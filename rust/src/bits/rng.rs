//! Deterministic PRNG used across tests, property harnesses, synthetic
//! datasets, and workload generators (no external `rand` crate in the
//! offline environment).

/// xorshift64* — small, fast, seedable, good enough for workload
/// generation and property tests (not cryptographic).
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Create from a seed. A zero seed is remapped to a fixed non-zero
    /// constant (xorshift has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Multiply-shift rejection-free mapping (slight bias acceptable
        // for simulation workloads).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform signed value in `[lo, hi]` inclusive.
    #[inline]
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.gen_range((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
            let v = r.gen_i64(-32, 31);
            assert!((-32..=31).contains(&v));
        }
    }

    #[test]
    fn gen_f64_unit_interval_and_roughly_uniform() {
        let mut r = XorShiftRng::new(99);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_normal_moments() {
        let mut r = XorShiftRng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShiftRng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = XorShiftRng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
