//! CLI subcommand implementations.

pub mod bench;
pub mod check;
pub mod eval;
pub mod infer;
pub mod info;
pub mod loadgen;
pub mod proxy;
pub mod replay;
pub mod report;
pub mod serve;
pub mod stats;
pub mod trace;

use impulse::config::RunConfig;
use impulse::Result;

/// Tiny flag parser: `--key value` pairs and bare flags.
pub struct Flags {
    pairs: Vec<(String, Option<String>)>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Flags {
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if val.is_some() {
                    i += 1;
                }
                pairs.push((key.to_string(), val));
            }
            i += 1;
        }
        Flags { pairs }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Every value given for a repeatable flag, in order (e.g. the
    /// proxy's `--backend a:1 --backend a:2`).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.pairs
            .iter()
            .filter(|(k, _)| k == key)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    pub fn has(&self, key: &str) -> bool {
        self.pairs.iter().any(|(k, _)| k == key)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key)?.parse().ok()
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key)?.parse().ok()
    }
}

/// Build the run config from `--config` plus flag overrides.
pub fn run_config(flags: &Flags) -> Result<RunConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(v) = flags.get_f64("vdd") {
        cfg.vdd = v;
    }
    if let Some(f) = flags.get_f64("freq-mhz") {
        cfg.freq_hz = f * 1e6;
    }
    if let Some(w) = flags.get_usize("workers") {
        cfg.workers = w.max(1);
    }
    if let Some(b) = flags.get_usize("batch") {
        cfg.batch = b.max(1);
    }
    if let Some(us) = flags.get_usize("batch-deadline-us") {
        cfg.batch_deadline_us = us as u64;
    }
    if flags.has("pipeline") {
        cfg.pipeline = true;
    }
    if flags.has("adaptive") {
        cfg.adaptive = true;
    }
    if let Some(addr) = flags.get("listen") {
        cfg.listen = Some(addr.to_string());
    }
    if flags.has("stdio") {
        // explicit stdio fallback wins over a configured listen addr
        cfg.listen = None;
    }
    if let Some(addr) = flags.get("metrics-listen") {
        cfg.metrics_listen = Some(addr.to_string());
    }
    if let Some(n) = flags.get_usize("queue-soft-limit") {
        cfg.queue_soft_limit = n as u64;
    }
    if let Some(n) = flags.get_usize("max-streams") {
        cfg.max_streams = n.max(1);
    }
    if let Some(n) = flags.get_usize("stream-ttl-s") {
        cfg.stream_ttl_s = (n as u64).max(1);
    }
    if let Some(n) = flags.get_usize("max") {
        cfg.max_samples = n;
    }
    if let Some(dir) = flags.get("trace-dir") {
        cfg.trace_dir = Some(dir.to_string());
    }
    if let Some(l) = flags.get("log-level") {
        anyhow::ensure!(
            impulse::obs::log::parse_level(l).is_some(),
            "unknown --log-level '{l}' (error|warn|info|debug)"
        );
        cfg.log_level = Some(l.to_string());
    }
    // initialize the stderr logger here so every config-driven
    // subcommand gets leveled logging (--log-level wins, then
    // IMPULSE_LOG, then info)
    impulse::obs::log::init(cfg.log_level.as_deref());
    Ok(cfg)
}
