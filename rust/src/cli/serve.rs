//! `impulse serve` — the inference server front-end.
//!
//! Two transports over the same [`impulse::serve::ServeCore`] request
//! path (so a given request answers bit-identically on either):
//!
//! - `--listen <addr>` (or `[run] listen` in the config): a
//!   multi-client TCP listener speaking the length-prefixed binary
//!   frame protocol of `docs/PROTOCOL.md`.
//! - `--stdio` (the default): one request per line on stdin:
//!       <id> <word_id> <word_id> …
//!   answered one per line on stdout:
//!       <id> <POSITIVE|NEGATIVE> v_out=<v> cycles=<c> us=<latency> batch=<n>
//!   or, when inference fails for a request:
//!       <id> ERROR <message>
//!   `quit` stops.
//!
//! Requests flow through the coordinator's micro-batching worker
//! pool: `--batch B` fuses up to B requests into one instruction
//! stream per tile (waiting at most `--batch-deadline-us`),
//! `--adaptive` sizes each batch from the queue depth instead, and
//! `--pipeline` runs unbatched requests through the wavefront layer
//! pipeline. Response `cycles` are the request's honest share of its
//! fused batch (per-request attribution, not an even split).

use super::Flags;
use impulse::coordinator::Response;
use impulse::data::{artifacts_dir, SentimentArtifacts};
use impulse::serve::{serve_tcp, ClientSession, ServeCore};
use impulse::snn::SentimentNetwork;
use impulse::Result;
use std::io::{BufRead, Write};
use std::sync::Arc;

fn write_response(out: &mut impl Write, r: &Response) -> Result<()> {
    if let Some(err) = &r.err {
        writeln!(out, "{} ERROR {}", r.id, err)?;
        return Ok(());
    }
    writeln!(
        out,
        "{} {} v_out={} cycles={} us={} batch={}",
        r.id,
        if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" },
        r.v_out,
        r.cycles,
        r.latency.as_micros(),
        r.batch_size,
    )?;
    Ok(())
}

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = Arc::new(SentimentArtifacts::load(artifacts_dir())?);
    let vocab = a.emb_q.len() as i64;
    let a2 = Arc::clone(&a);
    let mac = cfg.macro_config();
    let mut opts = cfg.server_options();
    if opts.adaptive {
        // probe the mapped model for its real fused-lane budget so
        // adaptive batches never exceed what one pass can fuse
        opts.adaptive_cap = SentimentNetwork::from_artifacts(&a, mac)?.max_batch_lanes();
    }
    let core = Arc::new(ServeCore::start_with(opts.clone(), vocab, move || {
        SentimentNetwork::from_artifacts(&a2, mac)
    })?);
    let batching = if opts.adaptive {
        "adaptive (queue-depth)".to_string()
    } else {
        format!("batch {} deadline {:?}", opts.batch_size, opts.batch_deadline)
    };
    match cfg.listen.as_deref() {
        Some(addr) => {
            let handle = serve_tcp(addr, Arc::clone(&core))?;
            eprintln!(
                "impulse serve: {} workers on tcp://{} ({batching}{}); \
                 binary frame protocol v{} (docs/PROTOCOL.md)",
                opts.workers,
                handle.local_addr(),
                if opts.pipeline { ", pipelined" } else { "" },
                impulse::serve::PROTOCOL_VERSION,
            );
            // Serve until the process is killed or the listener fails.
            handle.wait();
        }
        None => {
            let session = core.client()?;
            eprintln!(
                "impulse serve: {} workers on stdio ({batching}{}); \
                 send `<id> <word_id>…` lines, `quit` to stop",
                opts.workers,
                if opts.pipeline { ", pipelined" } else { "" },
            );
            run_stdio(&session)?;
            drop(session); // release the submit handle before shutdown
        }
    }
    core.shutdown();
    Ok(())
}

/// The line-oriented stdin/stdout loop over a shared-core session.
/// Every submitted request yields exactly one response (errors come
/// back as [`Response::err`]), so a submit/response counter pair is
/// the drain invariant; ready responses are drained opportunistically
/// between submits.
fn run_stdio(session: &ClientSession) -> Result<()> {
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    let mut pending = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        let mut it = line.split_whitespace();
        let id: u64 = match it.next().unwrap().parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("bad id in: {line}");
                continue;
            }
        };
        let word_ids: Vec<i64> = it.filter_map(|w| w.parse::<i64>().ok()).collect();
        if word_ids.is_empty() {
            eprintln!("request {id}: no word ids");
            continue;
        }
        session.submit(id, &word_ids)?;
        pending += 1;
        // drain whatever is ready without blocking the input loop
        while let Some(r) = session.try_recv() {
            pending -= 1;
            write_response(&mut stdout, &r)?;
        }
        stdout.flush()?;
    }
    // drain the rest
    while pending > 0 {
        let r = session.recv()?;
        pending -= 1;
        write_response(&mut stdout, &r)?;
    }
    stdout.flush()?;
    Ok(())
}
