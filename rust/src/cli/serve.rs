//! `impulse serve` — line-oriented inference server.
//!
//! Reads one request per line on stdin:
//!     <id> <word_id> <word_id> …
//! and writes one response per line on stdout:
//!     <id> <POSITIVE|NEGATIVE> v_out=<v> cycles=<c> us=<latency> batch=<n>
//! or, when inference fails for a request:
//!     <id> ERROR <message>
//!
//! Requests flow through the coordinator's micro-batching worker pool:
//! up to `--batch` requests (default 1) are fused into one instruction
//! stream per tile, waiting at most `--batch-deadline-us` for the
//! batch to fill; `--pipeline` runs unbatched requests through the
//! wavefront layer pipeline instead. `quit` stops.

use super::Flags;
use impulse::coordinator::{InferenceServer, Request, Response};
use impulse::data::{artifacts_dir, SentimentArtifacts};
use impulse::snn::SentimentNetwork;
use impulse::Result;
use std::io::{BufRead, Write};
use std::sync::Arc;

fn write_response(out: &mut impl Write, r: &Response) -> Result<()> {
    if let Some(err) = &r.err {
        writeln!(out, "{} ERROR {}", r.id, err)?;
        return Ok(());
    }
    writeln!(
        out,
        "{} {} v_out={} cycles={} us={} batch={}",
        r.id,
        if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" },
        r.v_out,
        r.cycles,
        r.latency.as_micros(),
        r.batch_size,
    )?;
    Ok(())
}

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = Arc::new(SentimentArtifacts::load(artifacts_dir())?);
    let vocab = a.emb_q.len() as i64;
    let a2 = Arc::clone(&a);
    let opts = cfg.server_options();
    let server = InferenceServer::start_with(opts.clone(), move || {
        SentimentNetwork::from_artifacts(&a2, cfg.macro_config())
    })?;
    eprintln!(
        "impulse serve: {} workers ready (batch {}, deadline {:?}{}); \
         send `<id> <word_id>…` lines, `quit` to stop",
        opts.workers,
        opts.batch_size,
        opts.batch_deadline,
        if opts.pipeline { ", pipelined" } else { "" },
    );

    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    // Every submitted request yields exactly one response (errors come
    // back as Response::err), so a submit/response counter pair is the
    // drain invariant; ready responses are drained opportunistically
    // on recv readiness rather than by comparing against inflight().
    let mut pending = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        let mut it = line.split_whitespace();
        let id: u64 = match it.next().unwrap().parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("bad id in: {line}");
                continue;
            }
        };
        let word_ids: Vec<i64> = it
            .filter_map(|w| w.parse::<i64>().ok())
            .map(|w| w.clamp(0, vocab - 1))
            .collect();
        if word_ids.is_empty() {
            eprintln!("request {id}: no word ids");
            continue;
        }
        server.submit(Request { id, word_ids })?;
        pending += 1;
        // drain whatever is ready without blocking the input loop
        while let Some(r) = server.try_recv() {
            pending -= 1;
            write_response(&mut stdout, &r)?;
        }
        stdout.flush()?;
    }
    // drain the rest
    while pending > 0 {
        let r = server.recv()?;
        pending -= 1;
        write_response(&mut stdout, &r)?;
    }
    stdout.flush()?;
    server.shutdown();
    Ok(())
}
