//! `impulse serve` — the inference server front-end.
//!
//! Two transports over the same [`impulse::serve::ServeCore`] request
//! path (so a given request answers bit-identically on either):
//!
//! - `--listen <addr>` (or `[run] listen` in the config): a
//!   multi-client TCP listener speaking the length-prefixed binary
//!   frame protocol of `docs/PROTOCOL.md`. SIGINT/SIGTERM drain
//!   in-flight requests and exit cleanly.
//! - `--stdio` (the default): one request per line on stdin:
//!       <id> <word_id> <word_id> …
//!   answered one per line on stdout:
//!       <id> <POSITIVE|NEGATIVE> v_out=<v> cycles=<c> us=<latency> batch=<n>
//!   or, when inference fails for a request:
//!       <id> ERROR <message>
//!   `quit` stops.
//!
//! `--model digits` serves the digits conv network instead of the
//! sentiment stack (framed transport only — `DigitsInferRequest`
//! payloads carry 28×28 images, which the line protocol cannot).
//!
//! Requests flow through the coordinator's micro-batching worker
//! pool: `--batch B` fuses up to B requests into one instruction
//! stream per tile (waiting at most `--batch-deadline-us`),
//! `--adaptive` sizes each batch from the queue depth instead, and
//! `--pipeline` runs unbatched requests through the wavefront layer
//! pipeline. Response `cycles` are the request's honest share of its
//! fused batch (per-request attribution, not an even split).
//!
//! `--trace-dir <dir>` records every request's lifecycle
//! (decode/queue/batch/execute/write spans) as Chrome trace-event
//! JSON rotations in `<dir>` — summarize with `impulse trace <dir>`
//! or load a rotation into Perfetto (`docs/OBSERVABILITY.md`).
//! `--log-level <error|warn|info|debug>` (or `IMPULSE_LOG`) sets the
//! stderr log verbosity.

use super::Flags;
use impulse::coordinator::{Response, WorkloadKind};
use impulse::data::{artifacts_dir, DigitsArtifacts, SentimentArtifacts};
use impulse::macro_sim::{ComparatorMode, Engine};
use impulse::obs::trace::{TraceFlusher, TraceRecorder};
use impulse::replay::Recorder;
use impulse::serve::{
    install_shutdown_handler, serve_tcp, ClientSession, ServeCore, TcpServeHandle,
};
use impulse::snn::{DigitsNetwork, SentimentNetwork};
use impulse::telemetry::{serve_metrics, Telemetry, Transport};
use impulse::Result;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn write_response(out: &mut impl Write, r: &Response) -> Result<()> {
    if let Some(err) = &r.err {
        writeln!(out, "{} ERROR {}", r.id, err)?;
        return Ok(());
    }
    match r.kind {
        WorkloadKind::Sentiment => writeln!(
            out,
            "{} {} v_out={} cycles={} us={} batch={}",
            r.id,
            if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" },
            r.v_out,
            r.cycles,
            r.latency.as_micros(),
            r.batch_size,
        )?,
        WorkloadKind::Digits => writeln!(
            out,
            "{} DIGIT {} v_out={} cycles={} us={} batch={}",
            r.id,
            r.pred,
            r.v_out,
            r.cycles,
            r.latency.as_micros(),
            r.batch_size,
        )?,
    }
    Ok(())
}

/// The capture-metadata name of an engine (`docs/REPLAY.md`; also the
/// `--engine` flag's accepted values).
pub(crate) fn engine_name(e: Engine) -> &'static str {
    match e {
        Engine::Fast => "fast",
        Engine::BitLevel => "bit",
        Engine::Lockstep => "lockstep",
    }
}

/// Parse an `--engine` flag value (the same names `[macro] engine`
/// accepts in config files).
pub(crate) fn parse_engine(v: &str) -> Result<Engine> {
    Ok(match v {
        "fast" => Engine::Fast,
        "bit" | "bit_level" => Engine::BitLevel,
        "lockstep" => Engine::Lockstep,
        other => anyhow::bail!("unknown engine '{other}' (fast|bit|lockstep)"),
    })
}

/// The capture-metadata name of a comparator mode.
pub(crate) fn comparator_name(c: ComparatorMode) -> &'static str {
    match c {
        ComparatorMode::SignBit => "sign",
        ComparatorMode::MsbCout => "cout",
    }
}

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let mut cfg = super::run_config(&flags)?;
    if let Some(v) = flags.get("engine") {
        cfg.engine = parse_engine(v)?;
    }
    // --record <dir>: tap every connection's wire traffic and
    // per-request V-digests into a capture (docs/REPLAY.md). The
    // capture must be re-executable, so scheduling nondeterminism is
    // pinned down: one worker, no batching, no pipelining.
    let record_dir = flags.get("record").map(PathBuf::from);
    if record_dir.is_some() {
        anyhow::ensure!(
            cfg.listen.is_some(),
            "--record requires --listen <addr>: recording taps the TCP transport"
        );
        cfg.workers = 1;
        cfg.batch = 1;
        cfg.adaptive = false;
        cfg.pipeline = false;
    }
    let mac = cfg.macro_config();
    let mut opts = cfg.server_options();
    opts.capture_digests = record_dir.is_some();
    // one registry for the whole process: the worker pool, the frame
    // listener, the stdio loop, and the metrics endpoint all share it
    let telemetry = Arc::new(Telemetry::new(cfg.telemetry_config()));
    opts.telemetry = Some(Arc::clone(&telemetry));
    // --trace-dir <dir>: per-request lifecycle tracing
    // (docs/OBSERVABILITY.md). Spans flush to Chrome trace-event JSON
    // rotations in the directory; inspect with `impulse trace <dir>`
    // or load a rotation into Perfetto / chrome://tracing.
    let trace_flusher = match cfg.trace_dir.as_deref() {
        Some(dir) => {
            let rec = Arc::new(TraceRecorder::new());
            opts.trace = Some(Arc::clone(&rec));
            impulse::info!(
                "serve",
                "tracing request lifecycles to {dir} (inspect with `impulse trace {dir}`)"
            );
            Some(TraceFlusher::start(rec, PathBuf::from(dir)))
        }
        None => None,
    };
    let model = flags.get("model").unwrap_or("sentiment");
    // --synthetic SEED serves the deterministic synthetic bundle
    // instead of the compiled artifacts: meaningful only for
    // differential work (record/replay, loadgen CI) — predictions are
    // not a trained model's
    let synthetic = flags.get_usize("synthetic").map(|s| s as u64);
    let core = match model {
        "sentiment" => {
            let a = Arc::new(match synthetic {
                Some(seed) => SentimentArtifacts::synthetic(seed),
                None => SentimentArtifacts::load(artifacts_dir())?,
            });
            let vocab = a.emb_q.len() as i64;
            if opts.adaptive {
                // probe the mapped model for its real fused-lane budget so
                // adaptive batches never exceed what one pass can fuse
                opts.adaptive_cap =
                    SentimentNetwork::from_artifacts(&a, mac)?.max_batch_lanes();
            }
            let a2 = Arc::clone(&a);
            Arc::new(ServeCore::start_with(opts.clone(), vocab, move || {
                SentimentNetwork::from_artifacts(&a2, mac)
            })?)
        }
        "digits" => {
            anyhow::ensure!(
                cfg.listen.is_some(),
                "digits serving is framed-protocol only: pass --listen <addr> \
                 (images do not fit the stdio line protocol)"
            );
            let a = Arc::new(match synthetic {
                Some(seed) => DigitsArtifacts::synthetic(seed),
                None => DigitsArtifacts::load(artifacts_dir())?,
            });
            if opts.adaptive {
                opts.adaptive_cap = DigitsNetwork::from_artifacts(&a, mac)?.max_batch_lanes();
            }
            let a2 = Arc::clone(&a);
            Arc::new(ServeCore::start_with(opts.clone(), 1, move || {
                DigitsNetwork::from_artifacts(&a2, mac)
            })?)
        }
        other => anyhow::bail!("unknown --model '{other}' (sentiment|digits)"),
    };
    // attach the recorder before the listener starts so the very
    // first accepted connection is already tapped
    let recorder = match &record_dir {
        Some(dir) => {
            let source = match synthetic {
                Some(seed) => format!("synthetic:{seed}"),
                None => "artifacts".to_string(),
            };
            let meta: Vec<(String, String)> = [
                ("protocol", impulse::serve::PROTOCOL_VERSION.to_string()),
                ("model", model.to_string()),
                ("source", source),
                ("engine", engine_name(cfg.engine).to_string()),
                ("comparator", comparator_name(cfg.comparator).to_string()),
                ("timesteps", cfg.timesteps.to_string()),
            ]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect();
            let (rec, path) = Recorder::to_dir(dir, &meta)?;
            let rec = Arc::new(rec);
            core.set_recorder(Arc::clone(&rec));
            impulse::info!(
                "serve",
                "recording wire traffic + V-digests to {} (replay with `impulse replay {}`)",
                path.display(),
                dir.display()
            );
            Some(rec)
        }
        None => None,
    };
    let batching = opts.batching_label();
    let metrics = match cfg.metrics_listen.as_deref() {
        Some(addr) => {
            let h = serve_metrics(addr, Arc::clone(&telemetry))?;
            impulse::info!(
                "serve",
                "metrics (Prometheus text) on http://{}/metrics (liveness on /healthz)",
                h.local_addr()
            );
            Some(h)
        }
        None => None,
    };
    match cfg.listen.as_deref() {
        Some(addr) => {
            let handle = serve_tcp(addr, Arc::clone(&core))?;
            impulse::info!(
                "serve",
                "{} {model} workers on tcp://{} ({batching}{}); \
                 binary frame protocol v{} (docs/PROTOCOL.md); \
                 `impulse stats {}` for live telemetry; \
                 SIGINT/SIGTERM drains and exits",
                opts.workers,
                handle.local_addr(),
                if opts.pipeline { ", pipelined" } else { "" },
                impulse::serve::PROTOCOL_VERSION,
                handle.local_addr(),
            );
            serve_until_signalled(handle);
        }
        None => {
            let session = core.client()?;
            impulse::info!(
                "serve",
                "{} workers on stdio ({batching}{}); \
                 send `<id> <word_id>…` lines, `quit` to stop",
                opts.workers,
                if opts.pipeline { ", pipelined" } else { "" },
            );
            run_stdio(&session, &telemetry)?;
            drop(session); // release the submit handle before shutdown
        }
    }
    if let Some(h) = metrics {
        h.stop();
    }
    core.shutdown();
    // stop tracing after the core drains so every in-flight request's
    // spans make the final rotation
    if let Some(f) = trace_flusher {
        f.stop();
    }
    if let Some(rec) = recorder {
        rec.flush()?;
        impulse::info!("serve", "capture complete ({} events)", rec.len());
    }
    Ok(())
}

/// Serve until SIGINT/SIGTERM arrives (→ drain and stop) or the
/// listener fails on its own. This is the graceful-shutdown path:
/// `TcpServeHandle::stop` winds down the accept loop and joins every
/// connection, whose responders flush all in-flight responses first.
fn serve_until_signalled(handle: TcpServeHandle) {
    let stop = install_shutdown_handler();
    while !stop.load(Ordering::SeqCst) && !handle.is_finished() {
        std::thread::sleep(Duration::from_millis(50));
    }
    if stop.load(Ordering::SeqCst) {
        impulse::info!("serve", "shutdown signal — draining in-flight requests…");
    }
    handle.stop();
    impulse::info!("serve", "stopped");
}

/// The line-oriented stdin/stdout loop over a shared-core session.
/// Every submitted request yields exactly one response (errors come
/// back as [`Response::err`]), so a submit/response counter pair is
/// the drain invariant; ready responses are drained opportunistically
/// between submits. Delivered responses are recorded on the `stdio`
/// transport's telemetry latency histogram.
fn run_stdio(session: &ClientSession, telemetry: &Telemetry) -> Result<()> {
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    let mut pending = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        let mut it = line.split_whitespace();
        let id: u64 = match it.next().unwrap().parse() {
            Ok(v) => v,
            Err(_) => {
                impulse::warn!("serve", "bad id in: {line}");
                continue;
            }
        };
        let word_ids: Vec<i64> = it.filter_map(|w| w.parse::<i64>().ok()).collect();
        if word_ids.is_empty() {
            impulse::warn!("serve", "request {id}: no word ids");
            continue;
        }
        if let Err(e) = session.submit(id, &word_ids) {
            // e.g. an oversized request — report it like any other
            // per-request failure and keep the loop alive
            writeln!(stdout, "{id} ERROR {e:#}")?;
        } else {
            pending += 1;
        }
        // drain whatever is ready without blocking the input loop
        while let Some(r) = session.try_recv() {
            pending -= 1;
            telemetry.record_wire(Transport::Stdio, r.latency);
            write_response(&mut stdout, &r)?;
        }
        stdout.flush()?;
    }
    // drain the rest
    while pending > 0 {
        let r = session.recv()?;
        pending -= 1;
        telemetry.record_wire(Transport::Stdio, r.latency);
        write_response(&mut stdout, &r)?;
    }
    stdout.flush()?;
    Ok(())
}
