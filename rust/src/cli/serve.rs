//! `impulse serve` — line-oriented inference server.
//!
//! Reads one request per line on stdin:
//!     <id> <word_id> <word_id> …
//! and writes one response per line on stdout:
//!     <id> <POSITIVE|NEGATIVE> v_out=<v> cycles=<c> us=<latency>
//!
//! Batched through the coordinator's worker pool; `quit` stops.

use super::Flags;
use impulse::coordinator::{InferenceServer, Request};
use impulse::data::{artifacts_dir, SentimentArtifacts};
use impulse::snn::SentimentNetwork;
use impulse::Result;
use std::io::{BufRead, Write};
use std::sync::Arc;

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = Arc::new(SentimentArtifacts::load(artifacts_dir())?);
    let vocab = a.emb_q.len() as i64;
    let mac = cfg.macro_config();
    let a2 = Arc::clone(&a);
    let server = InferenceServer::start(cfg.workers, move || {
        SentimentNetwork::from_artifacts(&a2, mac)
    })?;
    eprintln!(
        "impulse serve: {} workers ready; send `<id> <word_id>…` lines, `quit` to stop",
        cfg.workers
    );

    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    let mut pending = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        let mut it = line.split_whitespace();
        let id: u64 = match it.next().unwrap().parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("bad id in: {line}");
                continue;
            }
        };
        let word_ids: Vec<i64> = it
            .filter_map(|w| w.parse::<i64>().ok())
            .map(|w| w.clamp(0, vocab - 1))
            .collect();
        if word_ids.is_empty() {
            eprintln!("request {id}: no word ids");
            continue;
        }
        server.submit(Request { id, word_ids })?;
        pending += 1;
        // drain ready responses opportunistically
        while server.inflight() < pending {
            let r = server.recv()?;
            pending -= 1;
            writeln!(
                stdout,
                "{} {} v_out={} cycles={} us={}",
                r.id,
                if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" },
                r.v_out,
                r.cycles,
                r.latency.as_micros()
            )?;
        }
        stdout.flush()?;
    }
    // drain the rest
    while pending > 0 {
        let r = server.recv()?;
        pending -= 1;
        writeln!(
            stdout,
            "{} {} v_out={} cycles={} us={}",
            r.id,
            if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" },
            r.v_out,
            r.cycles,
            r.latency.as_micros()
        )?;
    }
    stdout.flush()?;
    server.shutdown();
    Ok(())
}
