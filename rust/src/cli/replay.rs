//! `impulse replay` — re-execute a recorded capture and verify
//! determinism.
//!
//! Loads a capture written by `impulse serve --record <dir>`, rebuilds
//! a serve core from the capture's metadata (model, artifact source,
//! engine, comparator, timesteps — pinned to one worker, no batching,
//! exactly as the recorder ran), replays every connection's inbound
//! bytes through a real TCP listener, and diffs response frames and
//! V-digest checkpoints against the recording. Exits nonzero on the
//! first divergence.
//!
//! `--engine fast|bit|lockstep` overrides the recorded engine: a
//! capture recorded on the SWAR fast path must replay bit-identically
//! on the bit-level engine (and vice versa) — the cross-engine
//! equivalence claim, now checkable on real recorded traffic.
//!
//! `--trace-dir <dir>` records the replayed requests' server-side
//! lifecycle spans (decode/queue/batch/execute/write) to a Chrome
//! trace-event JSON file — a way to profile a production capture's
//! timing offline (`docs/OBSERVABILITY.md`).

use super::serve::parse_engine;
use super::Flags;
use impulse::config::RunConfig;
use impulse::data::{artifacts_dir, DigitsArtifacts, SentimentArtifacts};
use impulse::macro_sim::ComparatorMode;
use impulse::obs::trace::{write_rotation, TraceRecorder};
use impulse::replay::{runner::replay_capture, Capture};
use impulse::serve::ServeCore;
use impulse::snn::{DigitsNetwork, SentimentNetwork};
use impulse::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;

pub fn run(args: &[String]) -> Result<()> {
    let dir = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| {
            anyhow::anyhow!("usage: impulse replay <capture-dir> [--engine fast|bit|lockstep]")
        })?;
    let flags = Flags::parse(args);
    impulse::obs::log::init(flags.get("log-level"));
    let capture = Capture::load(Path::new(dir))?;
    let trace_dir = flags.get("trace-dir").map(PathBuf::from);
    let trace = trace_dir.as_ref().map(|_| Arc::new(TraceRecorder::new()));
    let core = core_for(&capture, &flags, trace.clone())?;
    impulse::info!(
        "replay",
        "{} events from {dir} ({} / {} / engine {})",
        capture.events.len(),
        capture.meta_value("model").unwrap_or("sentiment"),
        capture.meta_value("source").unwrap_or("artifacts"),
        flags
            .get("engine")
            .unwrap_or_else(|| capture.meta_value("engine").unwrap_or("fast")),
    );
    let report = replay_capture(&capture, &core)?;
    core.shutdown();
    if let (Some(tdir), Some(tr)) = (&trace_dir, &trace) {
        let spans = tr.drain();
        let path = write_rotation(tdir, 0, &spans)?;
        impulse::info!(
            "replay",
            "wrote {} span(s) to {} (inspect with `impulse trace {}`)",
            spans.len(),
            path.display(),
            tdir.display()
        );
    }
    println!(
        "replayed {} connection(s): {} bytes in, {} response frame(s) and {} V-digest(s) compared",
        report.connections, report.bytes_in, report.frames_out, report.digests
    );
    match report.divergence {
        None => {
            println!("replay OK: bit-identical to the recording");
            Ok(())
        }
        Some(d) => anyhow::bail!("replay DIVERGED: {d}"),
    }
}

/// Rebuild the serving core a capture was recorded against, from its
/// metadata (with `--engine` as the one allowed override). A span
/// recorder, when given, traces every replayed request's lifecycle.
fn core_for(
    capture: &Capture,
    flags: &Flags,
    trace: Option<Arc<TraceRecorder>>,
) -> Result<Arc<ServeCore>> {
    let mut cfg = RunConfig {
        workers: 1,
        batch: 1,
        adaptive: false,
        pipeline: false,
        ..RunConfig::default()
    };
    if let Some(v) = capture.meta_value("engine") {
        cfg.engine = parse_engine(v)?;
    }
    if let Some(v) = flags.get("engine") {
        cfg.engine = parse_engine(v)?;
    }
    if let Some(v) = capture.meta_value("comparator") {
        cfg.comparator = match v {
            "sign" | "sign_bit" => ComparatorMode::SignBit,
            "cout" | "msb_cout" => ComparatorMode::MsbCout,
            other => anyhow::bail!("capture names unknown comparator '{other}'"),
        };
    }
    if let Some(v) = capture.meta_value("timesteps") {
        cfg.timesteps = v
            .parse()
            .map_err(|e| anyhow::anyhow!("capture timesteps '{v}': {e}"))?;
    }
    let mac = cfg.macro_config();
    let mut opts = cfg.server_options();
    opts.capture_digests = true;
    opts.trace = trace;
    let synthetic = match capture.meta_value("source") {
        Some(s) if s.starts_with("synthetic:") => Some(
            s["synthetic:".len()..]
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("capture source '{s}': {e}"))?,
        ),
        _ => None,
    };
    let core = match capture.meta_value("model").unwrap_or("sentiment") {
        "sentiment" => {
            let a = Arc::new(match synthetic {
                Some(seed) => SentimentArtifacts::synthetic(seed),
                None => SentimentArtifacts::load(artifacts_dir())?,
            });
            let vocab = a.emb_q.len() as i64;
            ServeCore::start_with(opts, vocab, move || SentimentNetwork::from_artifacts(&a, mac))?
        }
        "digits" => {
            let a = Arc::new(match synthetic {
                Some(seed) => DigitsArtifacts::synthetic(seed),
                None => DigitsArtifacts::load(artifacts_dir())?,
            });
            ServeCore::start_with(opts, 1, move || DigitsNetwork::from_artifacts(&a, mac))?
        }
        other => anyhow::bail!("capture names unknown model '{other}'"),
    };
    Ok(Arc::new(core))
}
