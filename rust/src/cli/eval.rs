//! `impulse eval` — evaluate the sentiment test set on the macro pool
//! (parallel via the coordinator's inference server), with optional
//! XLA cross-check. `impulse eval digits` evaluates the digits conv
//! network instead, through the same workload-generic server —
//! `--batch N` / `--adaptive` fuse images onto batch lanes.

use super::Flags;
use impulse::coordinator::{InferenceServer, Request};
use impulse::data::{
    artifacts_available, artifacts_dir, DigitsArtifacts, Manifest, SentimentArtifacts,
};
use impulse::energy::EnergyModel;
use impulse::metrics::eng;
use impulse::runtime::SentimentStepRuntime;
use impulse::snn::{DigitsNetwork, SentimentNetwork};
use impulse::Result;
use std::sync::Arc;
use std::time::Instant;

pub fn run(args: &[String]) -> Result<()> {
    if args.first().map(|s| s.as_str()) == Some("digits") {
        return run_digits(&args[1..]);
    }
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let dir = artifacts_dir();
    let a = Arc::new(SentimentArtifacts::load(&dir)?);
    let man = Manifest::read(dir.join("manifest.txt"))?;

    let n = if cfg.max_samples > 0 {
        cfg.max_samples.min(a.test_seqs.len())
    } else {
        a.test_seqs.len()
    };
    let batching = cfg.server_options().batching_label();
    println!(
        "evaluating {n} reviews on {} workers (engine {:?}, {batching})…",
        cfg.workers, cfg.engine
    );

    let mac = cfg.macro_config();
    // Built up front: probes the fused-lane budget for adaptive
    // batching and is reused for the energy histogram below.
    let mut net = SentimentNetwork::from_artifacts(&a, mac)?;
    let mut opts = cfg.server_options();
    if opts.adaptive {
        opts.adaptive_cap = net.max_batch_lanes();
    }
    let a2 = Arc::clone(&a);
    let server = InferenceServer::start_with(opts, move || {
        SentimentNetwork::from_artifacts(&a2, mac)
    })?;
    let t0 = Instant::now();
    let reqs: Vec<Request> = (0..n)
        .map(|i| Request::words(i as u64, a.test_seqs[i].clone()))
        .collect();
    let (responses, stats) = server.run_batch(reqs)?;
    let wall = t0.elapsed();
    server.shutdown();

    let failed = responses.iter().filter(|r| r.err.is_some()).count();
    if failed > 0 {
        for r in responses.iter().filter(|r| r.err.is_some()).take(5) {
            impulse::warn!(
                "eval",
                "review {} failed: {}",
                r.id,
                r.err.as_deref().unwrap_or("")
            );
        }
        impulse::warn!("eval", "{failed}/{n} reviews failed; accuracy is over the rest");
    }
    let ok = n - failed;
    let correct = responses
        .iter()
        .filter(|r| r.err.is_none() && r.pred == a.test_labels[r.id as usize])
        .count();
    let acc = correct as f64 / ok.max(1) as f64;
    println!("\naccuracy        : {acc:.4} ({correct}/{ok})");
    if let Some(m) = man.get_f64("snn_sentiment_quant_acc") {
        println!("python reference: {m:.4}");
    }
    if let Some(l) = man.get_f64("lstm_acc") {
        println!(
            "LSTM baseline   : {l:.4} ({} params vs {} → {:.1}×)",
            man.get("lstm_params").unwrap_or("?"),
            man.get("snn_sentiment_params").unwrap_or("?"),
            man.get_f64("lstm_params").unwrap_or(0.0)
                / man.get_f64("snn_sentiment_params").unwrap_or(1.0)
        );
    }
    println!("wall time       : {wall:?} ({:.1} reviews/s)", n as f64 / wall.as_secs_f64());
    println!("{}", stats.latency.report("latency"));

    let e = EnergyModel::calibrated();
    let per_review = stats.total_cycles as f64 / n as f64;
    println!(
        "macro cycles    : {} total, {per_review:.0}/review → {} @ {:.0} MHz",
        stats.total_cycles,
        eng(per_review / cfg.freq_hz, "s"),
        cfg.freq_hz / 1e6
    );
    // Energy: cycles are overwhelmingly AccW2V + the update sequences;
    // use the per-kind histogram of one review on the probe network.
    net.run_review(&a.test_seqs[0])?;
    let hist = net.stats().histogram;
    let e_one = e.program_energy_j(&hist, cfg.vdd);
    println!(
        "energy/review   : ≈{} at {:.2} V (first-review histogram)",
        eng(e_one, "J"),
        cfg.vdd
    );

    if flags.has("xla-check") {
        let k = 8.min(n);
        println!("\nXLA cross-check on {k} reviews…");
        let rt = SentimentStepRuntime::load(&dir, a.w1.len(), a.w1[0].len(), a.w2[0].len())?;
        let mut net = SentimentNetwork::from_artifacts(&a, cfg.macro_config())?;
        for i in 0..k {
            let (pred_xla, trace) = rt.run_review(&a.emb_q, &a.test_seqs[i], 10)?;
            let r = net.run_review(&a.test_seqs[i])?;
            let t64: Vec<i64> = trace.iter().map(|&v| v as i64).collect();
            anyhow::ensure!(
                r.vout_trace == t64 && r.pred == pred_xla,
                "review {i}: macro-sim and XLA disagree"
            );
        }
        println!("XLA cross-check : OK (bit-exact)");
    }
    Ok(())
}

/// `impulse eval digits [--max N] [--batch B | --adaptive]` — evaluate
/// the digits test images through the workload-generic inference
/// server (fused batch lanes on the conv + FC stack). Falls back to
/// the synthetic bundle when the compiled artifacts are absent so the
/// batched conv path can be exercised anywhere.
fn run_digits(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = Arc::new(if artifacts_available() {
        DigitsArtifacts::load(artifacts_dir())?
    } else {
        impulse::info!(
            "eval",
            "artifacts not built — evaluating on the synthetic digits bundle"
        );
        DigitsArtifacts::synthetic(2024)
    });
    anyhow::ensure!(!a.test_x.is_empty(), "digits bundle has no test images");
    let n = if cfg.max_samples > 0 {
        cfg.max_samples.min(a.test_x.len())
    } else {
        a.test_x.len()
    };
    let mac = cfg.macro_config();
    let probe = DigitsNetwork::from_artifacts(&a, mac)?;
    let mut opts = cfg.server_options();
    if opts.adaptive {
        opts.adaptive_cap = probe.max_batch_lanes();
    }
    let batching = opts.batching_label();
    println!(
        "evaluating {n} digit images on {} workers ({} fused lanes max, {batching})…",
        cfg.workers,
        probe.max_batch_lanes()
    );
    let a2 = Arc::clone(&a);
    let server = InferenceServer::start_with(opts, move || {
        DigitsNetwork::from_artifacts(&a2, mac)
    })?;
    let t0 = Instant::now();
    let reqs: Vec<Request> = (0..n)
        .map(|i| Request::image(i as u64, 28, 28, a.test_x[i].clone()))
        .collect();
    let (responses, stats) = server.run_batch(reqs)?;
    let wall = t0.elapsed();
    server.shutdown();

    let failed = responses.iter().filter(|r| r.err.is_some()).count();
    if failed > 0 {
        for r in responses.iter().filter(|r| r.err.is_some()).take(5) {
            impulse::warn!(
                "eval",
                "image {} failed: {}",
                r.id,
                r.err.as_deref().unwrap_or("")
            );
        }
        impulse::warn!("eval", "{failed}/{n} images failed; accuracy is over the rest");
    }
    let ok = n - failed;
    let correct = responses
        .iter()
        .filter(|r| r.err.is_none() && r.pred == a.test_y[r.id as usize])
        .count();
    println!(
        "\naccuracy        : {:.4} ({correct}/{ok})",
        correct as f64 / ok.max(1) as f64
    );
    println!(
        "wall time       : {wall:?} ({:.1} images/s)",
        n as f64 / wall.as_secs_f64()
    );
    println!("{}", stats.latency.report("latency"));
    let per_image = stats.total_cycles as f64 / n.max(1) as f64;
    println!(
        "macro cycles    : {} total, {per_image:.0}/image → {} @ {:.0} MHz",
        stats.total_cycles,
        eng(per_image / cfg.freq_hz, "s"),
        cfg.freq_hz / 1e6
    );
    Ok(())
}
