//! `impulse bench` — the machine-readable performance baseline.
//!
//! Runs the macro-throughput and sparsity sweeps that gate perf PRs
//! and (with `--json PATH`) writes the results — req/s, cycles/req,
//! ns/op per sparsity point, streaming-session throughput, git
//! revision — as JSON. CI runs this on the synthetic bundles and
//! uploads `BENCH_PR6.json` as an artifact, so the perf trajectory is
//! tracked from PR 5 onward.

use super::Flags;
use impulse::bench_harness::{Bencher, Table};
use impulse::bits::XorShiftRng;
use impulse::data::{artifacts_available, artifacts_dir, DigitsArtifacts, SentimentArtifacts};
use impulse::isa::InstructionKind;
use impulse::macro_sim::MacroConfig;
use impulse::snn::{DigitsNetwork, FcLayer, LayerParams, SentimentNetwork};
use impulse::Result;
use std::time::Duration;

/// One sparsity-sweep measurement (a 128→128 FC layer timestep).
struct SweepPoint {
    sparsity: f64,
    ns_per_step: f64,
    cycles_per_step: u64,
    accw2v_per_step: u64,
}

/// One serving measurement.
struct ServePoint {
    workload: &'static str,
    batch: usize,
    req_per_s: f64,
    cycles_per_req: f64,
}

/// One streaming-session measurement (pinned-membrane path).
struct StreamPoint {
    workload: &'static str,
    sparsity: f64,
    streams_per_s: f64,
    ns_per_append: f64,
}

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let budget = if flags.has("quick") {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(400)
    };
    let mut b = Bencher::new(budget);
    let mut rng = XorShiftRng::new(2024);

    // ---- sparsity sweep: FC layer timestep cost vs input sparsity ----
    println!("=== layer-timestep wall-clock vs input sparsity (128→128 RMP) ===\n");
    let weights: Vec<Vec<i64>> = (0..128)
        .map(|_| (0..128).map(|_| rng.gen_i64(-31, 31)).collect())
        .collect();
    let mut sweep = Vec::new();
    let mut t = Table::new(&["sparsity", "ns/step", "cycles/step", "AccW2V/step"]);
    for &s in &[0.0f64, 0.15, 0.5, 0.85, 1.0] {
        let mut layer = FcLayer::new(&weights, LayerParams::rmp(150), MacroConfig::fast())?;
        let spikes: Vec<bool> = (0..128).map(|_| rng.gen_bool(1.0 - s)).collect();
        layer.reset_counters();
        layer.step(&spikes)?;
        let st = layer.stats();
        let cycles_per_step = st.cycles;
        let accw2v_per_step = st
            .histogram
            .get(&InstructionKind::AccW2V)
            .copied()
            .unwrap_or(0);
        let r = b
            .bench(&format!("timestep @ {:.0}% sparsity", s * 100.0), 1, || {
                layer.step(&spikes).unwrap();
            })
            .clone();
        let ns_per_step = r.median.as_secs_f64() * 1e9;
        t.row(&[
            format!("{s:.2}"),
            format!("{ns_per_step:.0}"),
            format!("{cycles_per_step}"),
            format!("{accw2v_per_step}"),
        ]);
        sweep.push(SweepPoint {
            sparsity: s,
            ns_per_step,
            cycles_per_step,
            accw2v_per_step,
        });
    }
    println!("{}\n", t.render());

    // ---- serving throughput: sentiment + digits on the macro pool ----
    println!("=== serving throughput (synthetic bundles unless artifacts built) ===\n");
    let a = if artifacts_available() {
        SentimentArtifacts::load(artifacts_dir())?
    } else {
        SentimentArtifacts::synthetic(2024)
    };
    let vocab = a.emb_q.len() as i64;
    let n_reqs = 32usize;
    let reviews: Vec<Vec<i64>> = (0..n_reqs)
        .map(|i| (0..6).map(|j| ((i * 13 + j * 7) as i64) % vocab).collect())
        .collect();
    let refs: Vec<&[i64]> = reviews.iter().map(|r| r.as_slice()).collect();
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    let mut serving = Vec::new();
    let mut st = Table::new(&["workload", "batch", "req/s", "cycles/req"]);
    for &bsz in &[1usize, 16] {
        net.reset_counters();
        if bsz == 1 {
            for r in &refs {
                net.run_review(r)?;
            }
        } else {
            for chunk in refs.chunks(bsz) {
                net.run_reviews_batched(chunk)?;
            }
        }
        let cycles_per_req = net.stats().cycles as f64 / n_reqs as f64;
        let r = b
            .bench(&format!("sentiment batch={bsz}"), n_reqs as u64, || {
                if bsz == 1 {
                    for r in &refs {
                        net.run_review(r).unwrap();
                    }
                } else {
                    for chunk in refs.chunks(bsz) {
                        net.run_reviews_batched(chunk).unwrap();
                    }
                }
            })
            .clone();
        st.row(&[
            "sentiment".into(),
            format!("{bsz}"),
            format!("{:.1}", r.throughput_per_s),
            format!("{cycles_per_req:.0}"),
        ]);
        serving.push(ServePoint {
            workload: "sentiment",
            batch: bsz,
            req_per_s: r.throughput_per_s,
            cycles_per_req,
        });
    }
    if !flags.has("quick") {
        let da = if artifacts_available() {
            DigitsArtifacts::load(artifacts_dir())?
        } else {
            DigitsArtifacts::synthetic(2024)
        };
        let n_imgs = 8usize;
        let images: Vec<Vec<f32>> = (0..n_imgs)
            .map(|i| da.test_x[i % da.test_x.len()].clone())
            .collect();
        let img_refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        let mut dnet = DigitsNetwork::from_artifacts(&da, MacroConfig::fast())?;
        for &bsz in &[1usize, 8] {
            dnet.reset_counters();
            if bsz == 1 {
                for r in &img_refs {
                    dnet.run_image(r)?;
                }
            } else {
                for chunk in img_refs.chunks(bsz) {
                    dnet.run_images_batched(chunk)?;
                }
            }
            let cycles_per_req = dnet.stats().cycles as f64 / n_imgs as f64;
            let r = b
                .bench(&format!("digits batch={bsz}"), n_imgs as u64, || {
                    if bsz == 1 {
                        for r in &img_refs {
                            dnet.run_image(r).unwrap();
                        }
                    } else {
                        for chunk in img_refs.chunks(bsz) {
                            dnet.run_images_batched(chunk).unwrap();
                        }
                    }
                })
                .clone();
            st.row(&[
                "digits".into(),
                format!("{bsz}"),
                format!("{:.1}", r.throughput_per_s),
                format!("{cycles_per_req:.0}"),
            ]);
            serving.push(ServePoint {
                workload: "digits",
                batch: bsz,
                req_per_s: r.throughput_per_s,
                cycles_per_req,
            });
        }
    }
    println!("{}\n", st.render());

    // ---- streaming sessions: the pinned-membrane serve path ----
    println!("=== streaming sessions (membrane pinned across appends) ===\n");
    let mut streaming = Vec::new();
    let mut tt = Table::new(&["workload", "sparsity", "streams/s", "ns/append"]);
    {
        // sentiment: 6-word sessions, then steady-state single-word
        // appends on one long-lived stream (word inputs are dense —
        // sparsity 0)
        let session_ids: Vec<i64> = (0..6).map(|j| (j * 7) as i64 % vocab).collect();
        let mut snet = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
        let r = b
            .bench("sentiment stream session", 1, || {
                snet.begin_stream().unwrap();
                for &w in &session_ids {
                    snet.stream_words(&[w]).unwrap();
                }
                snet.stream_read_out();
            })
            .clone();
        let streams_per_s = r.throughput_per_s;
        snet.begin_stream()?;
        let ra = b
            .bench("sentiment stream append", 1, || {
                snet.stream_words(&[3]).unwrap();
            })
            .clone();
        let ns_per_append = ra.median.as_secs_f64() * 1e9;
        tt.row(&[
            "sentiment".into(),
            "0.00".into(),
            format!("{streams_per_s:.1}"),
            format!("{ns_per_append:.0}"),
        ]);
        streaming.push(StreamPoint {
            workload: "sentiment",
            sparsity: 0.0,
            streams_per_s,
            ns_per_append,
        });
    }
    if !flags.has("quick") {
        // digits: one image frame per append at 85% pixel sparsity —
        // the paper's operating point for the energy claims
        let da = if artifacts_available() {
            DigitsArtifacts::load(artifacts_dir())?
        } else {
            DigitsArtifacts::synthetic(2024)
        };
        let frame: Vec<f32> = (0..28 * 28)
            .map(|i| if (i * 13) % 100 < 15 { 0.8 } else { 0.0 })
            .collect();
        let mut dnet = DigitsNetwork::from_artifacts(&da, MacroConfig::fast())?;
        let r = b
            .bench("digits stream session", 1, || {
                dnet.begin_stream().unwrap();
                dnet.stream_image_step(&frame).unwrap();
                dnet.stream_image_step(&frame).unwrap();
                dnet.stream_read_out().unwrap();
            })
            .clone();
        let streams_per_s = r.throughput_per_s;
        dnet.begin_stream()?;
        dnet.stream_image_step(&frame)?; // prime the frame cache
        let ra = b
            .bench("digits stream append", 1, || {
                dnet.stream_image_step(&frame).unwrap();
            })
            .clone();
        let ns_per_append = ra.median.as_secs_f64() * 1e9;
        tt.row(&[
            "digits".into(),
            "0.85".into(),
            format!("{streams_per_s:.1}"),
            format!("{ns_per_append:.0}"),
        ]);
        streaming.push(StreamPoint {
            workload: "digits",
            sparsity: 0.85,
            streams_per_s,
            ns_per_append,
        });
    }
    println!("{}\n", tt.render());

    if let Some(path) = flags.get("json") {
        let json = render_json(&sweep, &serving, &streaming);
        std::fs::write(path, &json)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Hand-rolled JSON (no serde in the offline build) — flat schema, no
/// string content beyond the git revision.
fn render_json(sweep: &[SweepPoint], serving: &[ServePoint], streaming: &[StreamPoint]) -> String {
    let mut out = String::from("{\n");
    out.push_str("  \"schema\": \"impulse-bench-v1\",\n");
    out.push_str(&format!("  \"git_rev\": \"{}\",\n", git_rev()));
    out.push_str("  \"sparsity_sweep\": [\n");
    for (i, p) in sweep.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"sparsity\": {:.2}, \"ns_per_step\": {:.1}, \
             \"cycles_per_step\": {}, \"accw2v_per_step\": {}}}{}\n",
            p.sparsity,
            p.ns_per_step,
            p.cycles_per_step,
            p.accw2v_per_step,
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"serving\": [\n");
    for (i, p) in serving.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"batch\": {}, \"req_per_s\": {:.2}, \
             \"cycles_per_req\": {:.1}}}{}\n",
            p.workload,
            p.batch,
            p.req_per_s,
            p.cycles_per_req,
            if i + 1 < serving.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"streaming\": [\n");
    for (i, p) in streaming.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"sparsity\": {:.2}, \"streams_per_s\": {:.2}, \
             \"ns_per_append\": {:.1}}}{}\n",
            p.workload,
            p.sparsity,
            p.streams_per_s,
            p.ns_per_append,
            if i + 1 < streaming.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Best-effort revision stamp: CI's `GITHUB_SHA`, else `git
/// rev-parse`, else "unknown".
fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".into())
}
