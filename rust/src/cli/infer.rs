//! `impulse infer` — classify one review through the macro pool.
//!
//! `--stream` switches to the session-pinned streaming path: the
//! review is appended word-by-word over the framed protocol to a
//! server that keeps the membrane potentials pinned between appends
//! (an ephemeral in-process one by default, or `--addr <host:port>`
//! for a running `impulse serve --listen`). The final prediction is
//! bit-identical to the one-shot path on the same ids.

use super::Flags;
use impulse::coordinator::WorkloadInput;
use impulse::data::{artifacts_dir, SentimentArtifacts};
use impulse::energy::EnergyModel;
use impulse::metrics::eng;
use impulse::serve::{serve_tcp, FrameClient, ServeCore, TcpServeHandle, CAP_BACKPRESSURE};
use impulse::snn::SentimentNetwork;
use impulse::Result;
use std::sync::Arc;
use std::time::Duration;

/// The review to classify: explicit `--words`, or test sample
/// `--sample N` (default 0).
fn review_ids(flags: &Flags, a: &SentimentArtifacts) -> Result<Vec<i64>> {
    if let Some(words) = flags.get("words") {
        words
            .split_whitespace()
            .map(|w| w.parse::<i64>().map_err(|e| anyhow::anyhow!("bad id '{w}': {e}")))
            .collect::<Result<_>>()
    } else {
        let n = flags.get_usize("sample").unwrap_or(0);
        anyhow::ensure!(n < a.test_seqs.len(), "sample {n} out of range");
        Ok(a.test_seqs[n].clone())
    }
}

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    if flags.has("stream") {
        return run_stream(&flags);
    }
    let cfg = super::run_config(&flags)?;
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let mut net = SentimentNetwork::from_artifacts(&a, cfg.macro_config())?;
    let word_ids = review_ids(&flags, &a)?;

    let r = net.run_review(&word_ids)?;
    println!("prediction : {}", if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" });
    println!("V_out      : {}", r.v_out);
    println!("trace      : {:?}", r.vout_trace);
    println!("CIM cycles : {}", r.cycles);
    let e = EnergyModel::calibrated();
    let energy = e.program_energy_j(&net.stats().histogram, cfg.vdd);
    println!(
        "energy     : {} at {:.2} V (delay {} at {:.0} MHz)",
        eng(energy, "J"),
        cfg.vdd,
        eng(e.delay_s(r.cycles, cfg.freq_hz), "s"),
        cfg.freq_hz / 1e6
    );
    if let Some(n) = flags.get_usize("sample") {
        println!("label      : {}", a.test_labels[n]);
    }
    Ok(())
}

/// `impulse infer --stream` — append the review word-by-word to a
/// pinned streaming session and read the running prediction out after
/// every chunk.
fn run_stream(flags: &Flags) -> Result<()> {
    let cfg = super::run_config(flags)?;
    let a = Arc::new(SentimentArtifacts::load(artifacts_dir())?);
    let word_ids = review_ids(flags, &a)?;
    anyhow::ensure!(!word_ids.is_empty(), "nothing to stream");

    // --addr streams against a running server; otherwise spin an
    // ephemeral in-process one on a loopback port
    let mut local: Option<(Arc<ServeCore>, TcpServeHandle)> = None;
    let addr = match flags.get("addr") {
        Some(addr) => addr.to_string(),
        None => {
            let mac = cfg.macro_config();
            let vocab = a.emb_q.len() as i64;
            let a2 = Arc::clone(&a);
            let core = Arc::new(ServeCore::start_with(cfg.server_options(), vocab, move || {
                SentimentNetwork::from_artifacts(&a2, mac)
            })?);
            let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core))?;
            let addr = handle.local_addr().to_string();
            local = Some((core, handle));
            addr
        }
    };

    let mut client = FrameClient::connect(addr.as_str())?;
    let (ver, caps) = client.hello_with_caps(CAP_BACKPRESSURE)?;
    if caps & CAP_BACKPRESSURE != 0 {
        // back off between appends when the server signals soft-limit
        client.enable_pacing(Duration::from_micros(500), Duration::from_millis(50));
    }
    let h = client.stream_open()?;
    println!("stream     : id {} on lane {} of {addr} (protocol v{ver})", h.id(), h.lane());
    for (i, &wid) in word_ids.iter().enumerate() {
        let ack = client.stream_append(&h, &WorkloadInput::Words(vec![wid]))?;
        let out = client.stream_read_out(&h)?;
        println!(
            "word {i:>3} id {wid:>6} → {} v_out={} cycles={}",
            if out.pred == 1 { "POSITIVE" } else { "NEGATIVE" },
            out.v_out,
            ack.cycles,
        );
    }
    let fin = client.stream_close(&h)?;
    println!(
        "final      : {} cycles across {} words (membrane pinned server-side)",
        fin.cycles,
        word_ids.len(),
    );
    if let Some(n) = flags.get_usize("sample") {
        println!("label      : {}", a.test_labels[n]);
    }
    if let Some((core, handle)) = local {
        handle.stop();
        core.shutdown();
    }
    Ok(())
}

/// `impulse trace-vmem` — Fig 10: the output neuron's membrane
/// potential after each word, rendered as an ASCII trajectory.
pub fn trace_vmem(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let mut net = SentimentNetwork::from_artifacts(&a, cfg.macro_config())?;
    let n = flags.get_usize("sample").unwrap_or(0);
    anyhow::ensure!(n < a.test_seqs.len(), "sample {n} out of range");
    let r = net.run_review(&a.test_seqs[n])?;
    println!(
        "review #{n} (label {}): V_out per word → {}",
        a.test_labels[n],
        if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" }
    );
    let max = r.vout_trace.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
    for (w, &v) in r.vout_trace.iter().enumerate() {
        let width = ((v.abs() as f64 / max as f64) * 28.0) as usize;
        if v >= 0 {
            println!("word {w:>2} {v:>6} {:>28}|{}", "", "#".repeat(width));
        } else {
            println!(
                "word {w:>2} {v:>6} {:>pad$}{}|",
                "",
                "#".repeat(width),
                pad = 28 - width
            );
        }
    }
    Ok(())
}
