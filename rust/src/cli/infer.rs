//! `impulse infer` — classify one review through the macro pool.

use super::Flags;
use impulse::data::{artifacts_dir, SentimentArtifacts};
use impulse::energy::EnergyModel;
use impulse::metrics::eng;
use impulse::snn::SentimentNetwork;
use impulse::Result;

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let mut net = SentimentNetwork::from_artifacts(&a, cfg.macro_config())?;

    let word_ids: Vec<i64> = if let Some(words) = flags.get("words") {
        words
            .split_whitespace()
            .map(|w| w.parse::<i64>().map_err(|e| anyhow::anyhow!("bad id '{w}': {e}")))
            .collect::<Result<_>>()?
    } else {
        let n = flags.get_usize("sample").unwrap_or(0);
        anyhow::ensure!(n < a.test_seqs.len(), "sample {n} out of range");
        a.test_seqs[n].clone()
    };

    let r = net.run_review(&word_ids)?;
    println!("prediction : {}", if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" });
    println!("V_out      : {}", r.v_out);
    println!("trace      : {:?}", r.vout_trace);
    println!("CIM cycles : {}", r.cycles);
    let e = EnergyModel::calibrated();
    let energy = e.program_energy_j(&net.stats().histogram, cfg.vdd);
    println!(
        "energy     : {} at {:.2} V (delay {} at {:.0} MHz)",
        eng(energy, "J"),
        cfg.vdd,
        eng(e.delay_s(r.cycles, cfg.freq_hz), "s"),
        cfg.freq_hz / 1e6
    );
    if let Some(n) = flags.get_usize("sample") {
        println!("label      : {}", a.test_labels[n]);
    }
    Ok(())
}

/// `impulse trace-vmem` — Fig 10: the output neuron's membrane
/// potential after each word, rendered as an ASCII trajectory.
pub fn trace_vmem(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let cfg = super::run_config(&flags)?;
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let mut net = SentimentNetwork::from_artifacts(&a, cfg.macro_config())?;
    let n = flags.get_usize("sample").unwrap_or(0);
    anyhow::ensure!(n < a.test_seqs.len(), "sample {n} out of range");
    let r = net.run_review(&a.test_seqs[n])?;
    println!(
        "review #{n} (label {}): V_out per word → {}",
        a.test_labels[n],
        if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" }
    );
    let max = r.vout_trace.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
    for (w, &v) in r.vout_trace.iter().enumerate() {
        let width = ((v.abs() as f64 / max as f64) * 28.0) as usize;
        if v >= 0 {
            println!("word {w:>2} {v:>6} {:>28}|{}", "", "#".repeat(width));
        } else {
            println!(
                "word {w:>2} {v:>6} {:>pad$}{}|",
                "",
                "#".repeat(width),
                pad = 28 - width
            );
        }
    }
    Ok(())
}
