//! `impulse report` — regenerate the paper's figures and table.

use super::Flags;
use impulse::baselines::{table1_rows, VanillaAccelModel};
use impulse::bench_harness::Table;
use impulse::energy::{
    AreaModel, EnergyModel, ShmooModel, SparsitySweep, OPERATING_POINTS,
};
use impulse::isa::{InstructionKind, NeuronType};
use impulse::metrics::eng;
use impulse::{Result, NOMINAL_FREQ_HZ, NOMINAL_VDD};

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    if let Some(fig) = flags.get("fig") {
        match fig {
            "2" => fig2(),
            "6" => fig6(),
            "7" => fig7(),
            "8" => shmoo()?,
            "9a" => fig9a(),
            "11b" => sweep(args)?,
            other => anyhow::bail!(
                "no figure '{other}' (have 2, 6, 7, 8, 9a, 11b; 9b/10/11a are e2e examples)"
            ),
        }
        return Ok(());
    }
    if flags.get("table") == Some("1") {
        table1();
        return Ok(());
    }
    anyhow::bail!("usage: impulse report --fig {{2|6|7|8|9a|11b}} | --table 1")
}

/// Fig 2: the motivation numbers — fused CIM vs separate-SRAM strawman.
fn fig2() {
    let e = EnergyModel::calibrated();
    let v = VanillaAccelModel::new(&e);
    println!("Fig 2 — fused W/V CIM vs separate-SRAM accelerator (energy ratio)\n");
    let mut t = Table::new(&["sparsity", "separate-SRAM (pJ)", "IMPULSE (pJ)", "ratio"]);
    for s in [0.0, 0.5, 0.85, 0.95] {
        let van = v.timestep_energy_j(s, NeuronType::RMP, NOMINAL_VDD) * 1e12;
        let imp = v.impulse_timestep_energy_j(s, NeuronType::RMP, NOMINAL_VDD) * 1e12;
        t.row(&[
            format!("{s:.2}"),
            format!("{van:.2}"),
            format!("{imp:.2}"),
            format!("{:.2}×", van / imp),
        ]);
    }
    println!("{}", t.render());
}

/// Fig 6: neuron types, sequences, energy per update.
fn fig6() {
    let e = EnergyModel::calibrated();
    let tbl = e.instr_table(NOMINAL_VDD);
    println!("Fig 6 — neuron functionality via in-memory instruction sequences");
    println!("(measured at 200 MHz @ 0.85 V; paper: IF 1.81, LIF 2.67, RMP 1.68 pJ)\n");
    let mut t = Table::new(&["neuron", "instruction sequence", "energy/update (pJ)"]);
    t.row(&[
        "IF".into(),
        "SpikeCheck; ResetV".into(),
        format!("{:.2}", tbl.spike_check_pj + tbl.reset_v_pj),
    ]);
    t.row(&[
        "LIF".into(),
        "AccV2V(-leak); SpikeCheck; ResetV".into(),
        format!("{:.2}", tbl.acc_v2v_pj + tbl.spike_check_pj + tbl.reset_v_pj),
    ]);
    t.row(&[
        "RMP".into(),
        "SpikeCheck; AccV2V(-θ, spiked)".into(),
        format!("{:.2}", tbl.spike_check_pj + tbl.acc_v2v_pj),
    ]);
    println!("{}", t.render());
}

/// Fig 7: area breakdown.
fn fig7() {
    let b = AreaModel::calibrated().breakdown();
    println!("Fig 7 — die area breakdown (65 nm; paper: 0.089 mm², 54.2% memory)\n");
    let mut t = Table::new(&["component", "area (mm²)", "share"]);
    let total = b.total_mm2();
    for (name, a) in [
        ("10T bitcell arrays (W_MEM+V_MEM)", b.bitcells_mm2),
        ("reconfigurable column peripherals", b.column_periph_mm2),
        ("triple-row decoders", b.decoders_mm2),
        ("control + spike buffers + timing", b.control_mm2),
    ] {
        t.row(&[
            name.into(),
            format!("{a:.4}"),
            format!("{:.1}%", 100.0 * a / total),
        ]);
    }
    t.row(&["TOTAL".into(), format!("{total:.3}"), "100%".into()]);
    println!("{}", t.render());
    println!("memory area efficiency: {:.1}%", 100.0 * b.memory_efficiency());
}

/// Fig 8: the Shmoo plot.
pub fn shmoo() -> Result<()> {
    let m = ShmooModel::calibrated();
    println!("Fig 8 — Shmoo ( # = CIM+R/W pass, R = only read/write pass, . = fail )\n");
    print!("{}", m.standard_grid().render());
    println!("             VDD 0.6 → 1.2 V (x), frequency ↑ (y)");
    println!("\nCIM boundary points (published): 0.70V/66.67MHz, 0.85V/200MHz, 1.20V/500MHz");
    Ok(())
}

/// Fig 9a: power + efficiency at operating points A–G.
fn fig9a() {
    let e = EnergyModel::calibrated();
    println!("Fig 9a — AccW2V power & energy-efficiency at Shmoo points A–G\n");
    let mut t = Table::new(&["point", "VDD (V)", "f (MHz)", "power", "TOPS/W", "measured (paper)"]);
    for p in OPERATING_POINTS {
        let pw = e.avg_power_w(p.vdd, p.freq_hz);
        let eff = e.tops_per_w(InstructionKind::AccW2V, p.vdd, p.freq_hz);
        t.row(&[
            p.label.into(),
            format!("{:.2}", p.vdd),
            format!("{:.2}", p.freq_hz / 1e6),
            eng(pw, "W"),
            format!("{eff:.3}"),
            p.measured_power_w
                .map(|w| eng(w, "W"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    println!("per-instruction TOPS/W at point D (paper: 0.99 / 1.18 / 1.02 / 1.22):");
    for k in InstructionKind::CIM {
        println!(
            "  {:<11} {:.3}",
            k.name(),
            e.tops_per_w(k, NOMINAL_VDD, NOMINAL_FREQ_HZ)
        );
    }
}

/// Fig 11b: EDP vs sparsity.
pub fn sweep(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let neuron = flags
        .get("neuron")
        .map(|s| NeuronType::parse(s).ok_or_else(|| anyhow::anyhow!("bad neuron '{s}'")))
        .transpose()?
        .unwrap_or(NeuronType::RMP);
    let e = EnergyModel::calibrated();
    let sweep = SparsitySweep::run(&e, neuron, 20);
    println!("Fig 11b — EDP per neuron per timestep vs input sparsity ({neuron:?})\n");
    let mut t = Table::new(&[
        "sparsity", "energy (pJ)", "delay (ns)", "EDP (aJ·s ×1e-?)", "vs s=0",
    ]);
    let base = sweep.points[0].edp;
    for p in &sweep.points {
        t.row(&[
            format!("{:.2}", p.sparsity),
            format!("{:.3}", p.energy_j * 1e12),
            format!("{:.3}", p.delay_s * 1e9),
            format!("{:.4e}", p.edp),
            format!("-{:.1}%", 100.0 * (1.0 - p.edp / base)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "EDP reduction at 85% sparsity: {:.1}%  (paper: 97.4%)",
        100.0 * sweep.reduction_at(0.85)
    );
    Ok(())
}

/// Table I.
fn table1() {
    let rows = table1_rows(&EnergyModel::calibrated(), &AreaModel::calibrated());
    println!("Table I — comparison with other SNN and CIM macros\n");
    let mut t = Table::new(&[
        "macro", "tech", "app", "type", "precision", "cell", "flex-neuron",
        "sparsity", "area mm²", "V", "MHz", "mW", "GOPS/mm²", "TOPS/W",
    ]);
    for r in rows {
        t.row(&[
            r.name.into(),
            format!("{}nm", r.technology_nm),
            r.application.into(),
            r.macro_type.into(),
            r.precision.into(),
            r.bitcell.into(),
            if r.flexible_neuron { "Yes" } else { "No" }.into(),
            if r.sparsity_support { "Yes" } else { "No" }.into(),
            r.area_mm2.map(|a| format!("{a:.4}")).unwrap_or("-".into()),
            format!("{:.2}", r.supply_v),
            format!("{:.2}", r.freq_mhz),
            r.power_mw.map(|p| format!("{p:.3}")).unwrap_or("-".into()),
            r.gops_per_mm2.map(|g| format!("{g:.2}")).unwrap_or("-".into()),
            r.tops_per_w.map(|t| format!("{t:.3}")).unwrap_or("-".into()),
        ]);
    }
    println!("{}", t.render());
}
