//! `impulse info` — artifact bundle + model summary.

use impulse::data::{artifacts_available, artifacts_dir, Manifest, SentimentArtifacts};
use impulse::energy::AreaModel;
use impulse::macro_sim::MacroConfig;
use impulse::snn::SentimentNetwork;
use impulse::Result;

pub fn run() -> Result<()> {
    println!("IMPULSE reproduction — artifact & model summary\n");
    let dir = artifacts_dir();
    println!("artifacts dir : {}", dir.display());
    if !artifacts_available() {
        println!("artifacts     : NOT BUILT (run `make artifacts`)");
        return Ok(());
    }
    let man = Manifest::read(dir.join("manifest.txt"))?;
    for key in [
        "snn_sentiment_params",
        "snn_sentiment_float_acc",
        "snn_sentiment_quant_acc",
        "lstm_params",
        "lstm_acc",
        "snn_digits_quant_acc",
        "build_seconds",
        "source_digest",
    ] {
        if let Some(v) = man.get(key) {
            println!("{key:<26}: {v}");
        }
    }
    let a = SentimentArtifacts::load(&dir)?;
    let net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    println!("\nsentiment network:");
    println!("  mapped params   : {}", net.num_params());
    println!("  macros (tiles)  : {}", net.num_macros());
    println!(
        "  thresholds      : enc={} θ1={} θ2={}",
        a.thr_enc, a.thr1, a.thr2
    );
    let area = AreaModel::calibrated();
    println!(
        "  silicon budget  : {:.3} mm² per macro → {:.3} mm² pool",
        area.breakdown().total_mm2(),
        area.breakdown().total_mm2() * net.num_macros() as f64
    );
    Ok(())
}
