//! `impulse check` — static analysis of the built-in ISA streams.
//!
//! Runs the shared [`ProgramValidator`] (structural rules + dataflow
//! linter, see docs/VALIDATION.md) over every instruction stream the
//! coordinator can emit: the canonical Fig 6 neuron sequences and one
//! representative tile schedule per layer of both model networks,
//! built from the deterministic synthetic bundles so no compiled
//! artifacts are needed. Exits nonzero if any stream produces an
//! Error-severity diagnostic; warnings are reported but do not fail.

use super::Flags;
use impulse::bitcell::Parity;
use impulse::data::{DigitsArtifacts, SentimentArtifacts};
use impulse::isa::{neuron_sequence, NeuronType, Program, ProgramValidator};
use impulse::macro_sim::MacroConfig;
use impulse::mapper::ConstRows;
use impulse::snn::{DigitsNetwork, SentimentNetwork};
use impulse::Result;

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let json = flags.has("json");
    let model = flags.get("model").unwrap_or("all");
    let timesteps = flags.get_usize("timesteps").unwrap_or(2).max(1);
    let seed = flags.get_usize("seed").unwrap_or(7) as u64;

    // (label, program, validator) triples. Neuron sequences are
    // fragments — constants and membranes live outside the fragment —
    // so they run with `assume_initialized`; full schedules install
    // their own state and run strict.
    let mut streams: Vec<(String, Program, ProgramValidator)> = Vec::new();

    let fragment = ProgramValidator::new().assume_initialized(true);
    let cr = ConstRows::default();
    for (ty, name) in [
        (NeuronType::IF, "if"),
        (NeuronType::LIF, "lif"),
        (NeuronType::RMP, "rmp"),
    ] {
        for (parity, pname) in [(Parity::Odd, "odd"), (Parity::Even, "even")] {
            let v_row = match parity {
                Parity::Odd => 0,
                Parity::Even => 1,
            };
            let seq = neuron_sequence(ty, v_row, cr.for_parity(parity), parity);
            streams.push((format!("seq/{name}/{pname}"), Program::from_vec(seq), fragment));
        }
    }

    let strict = ProgramValidator::new();
    if model == "all" || model == "sentiment" {
        let a = SentimentArtifacts::synthetic(seed);
        let net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
        for (label, prog) in net.schedule_programs(timesteps) {
            streams.push((format!("sentiment/{label}"), prog, strict));
        }
    }
    if model == "all" || model == "digits" {
        let a = DigitsArtifacts::synthetic(seed);
        let net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast())?;
        for (label, prog) in net.schedule_programs(timesteps) {
            streams.push((format!("digits/{label}"), prog, strict));
        }
    }
    if streams.is_empty() {
        anyhow::bail!("unknown --model '{model}' (expected sentiment|digits|all)");
    }

    let mut errors = 0usize;
    let mut warnings = 0usize;
    let mut json_entries = Vec::new();
    for (label, prog, validator) in &streams {
        let report = validator.validate(prog);
        errors += report.error_count();
        warnings += report.warning_count();
        if json {
            json_entries.push(format!(
                "{{\"stream\":\"{label}\",\"report\":{}}}",
                report.to_json()
            ));
        } else {
            let status = if report.error_count() > 0 {
                "FAIL"
            } else if report.warning_count() > 0 {
                "warn"
            } else {
                "ok"
            };
            println!(
                "{status:>4}  {label} ({} instructions, {} errors, {} warnings)",
                report.instructions(),
                report.error_count(),
                report.warning_count(),
            );
            for d in report.diagnostics() {
                println!("      {d}");
            }
        }
    }

    if json {
        println!("[{}]", json_entries.join(","));
    } else {
        println!(
            "checked {} streams: {errors} errors, {warnings} warnings",
            streams.len()
        );
    }
    if errors > 0 {
        anyhow::bail!("validation failed: {errors} error diagnostics");
    }
    Ok(())
}
