//! `impulse trace` — summarize an exported trace directory offline.
//!
//! Loads every `trace-*.json` rotation written by `impulse serve
//! --trace-dir` (or `loadgen`/`replay` with the same flag), prints a
//! per-phase latency table (count, p50, p99, max over span durations)
//! and the slowest complete traces with their per-phase breakdown and
//! execute-span cost attributes. `--slowest N` widens the listing
//! (default 5); `--json` emits the same summary as one machine-
//! readable JSON object. The files themselves stay Chrome trace-event
//! documents — load them in Perfetto for the visual timeline
//! (`docs/OBSERVABILITY.md`).

use impulse::obs::trace::{load_trace_dir, Phase, TraceEvent};
use impulse::Result;
use std::collections::BTreeMap;
use std::path::Path;

pub fn run(args: &[String]) -> Result<()> {
    let dir = args.first().filter(|a| !a.starts_with("--")).ok_or_else(|| {
        anyhow::anyhow!("usage: impulse trace <trace-dir> [--slowest N] [--json]")
    })?;
    let flags = super::Flags::parse(args);
    let slowest = flags.get_usize("slowest").unwrap_or(5);
    let events = load_trace_dir(Path::new(dir))?;
    anyhow::ensure!(!events.is_empty(), "no trace events under {dir} (expected trace-*.json)");
    let summary = summarize(&events, slowest);
    if flags.has("json") {
        println!("{}", summary.to_json());
    } else {
        print!("{}", summary.render(dir));
    }
    Ok(())
}

/// Phase-name display order: the request lifecycle first, then the
/// auxiliary span kinds, then anything a foreign trace file added.
const PHASE_ORDER: [&str; 7] =
    ["decode", "queue", "batch", "execute", "write", "stream_append", "client"];

fn phase_rank(name: &str) -> usize {
    PHASE_ORDER.iter().position(|p| *p == name).unwrap_or(PHASE_ORDER.len())
}

/// Per-phase duration statistics over every event with that name.
struct PhaseStats {
    name: String,
    count: usize,
    p50_us: u64,
    p99_us: u64,
    max_us: u64,
}

/// One trace's rollup: which request it served and where its time went.
struct TraceRollup {
    trace_id: u64,
    request_id: u64,
    conn: u64,
    total_us: u64,
    /// `(phase name, summed duration)` in display order.
    phases: Vec<(String, u64)>,
    worker: u64,
    batch: u64,
    cycles: u64,
    energy_fj: u64,
    ok: bool,
}

struct Summary {
    events: usize,
    traces: usize,
    phases: Vec<PhaseStats>,
    slowest: Vec<TraceRollup>,
}

/// Index into a sorted sample at quantile `q`/100 (rounded rank).
fn pct(sorted: &[u64], q: u64) -> u64 {
    match sorted.len() {
        0 => 0,
        n => sorted[((n as u64 - 1) * q + 50) as usize / 100],
    }
}

fn summarize(events: &[TraceEvent], slowest: usize) -> Summary {
    let mut by_phase: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
    let mut by_trace: BTreeMap<u64, TraceRollup> = BTreeMap::new();
    for e in events {
        by_phase.entry(&e.name).or_default().push(e.dur);
        let t = by_trace.entry(e.trace_id).or_insert_with(|| TraceRollup {
            trace_id: e.trace_id,
            request_id: e.request_id,
            conn: e.conn,
            total_us: 0,
            phases: Vec::new(),
            worker: 0,
            batch: 0,
            cycles: 0,
            energy_fj: 0,
            ok: true,
        });
        t.total_us += e.dur;
        t.ok &= e.ok;
        match t.phases.iter_mut().find(|(n, _)| *n == e.name) {
            Some((_, d)) => *d += e.dur,
            None => t.phases.push((e.name.clone(), e.dur)),
        }
        if e.name == Phase::Execute.name() {
            t.worker = e.worker;
            t.batch = e.batch;
            t.cycles += e.cycles;
            t.energy_fj += e.energy_fj;
        }
    }
    let mut phases: Vec<PhaseStats> = by_phase
        .into_iter()
        .map(|(name, mut durs)| {
            durs.sort_unstable();
            PhaseStats {
                name: name.to_string(),
                count: durs.len(),
                p50_us: pct(&durs, 50),
                p99_us: pct(&durs, 99),
                max_us: *durs.last().unwrap_or(&0),
            }
        })
        .collect();
    phases.sort_by_key(|p| (phase_rank(&p.name), p.name.clone()));
    let traces = by_trace.len();
    let mut rollups: Vec<TraceRollup> = by_trace.into_values().collect();
    for t in &mut rollups {
        t.phases.sort_by_key(|(n, _)| (phase_rank(n), n.clone()));
    }
    rollups.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.trace_id.cmp(&b.trace_id)));
    rollups.truncate(slowest);
    Summary { events: events.len(), traces, phases, slowest: rollups }
}

impl Summary {
    fn render(&self, dir: &str) -> String {
        let mut out = format!(
            "{} event(s) across {} trace(s) from {dir}\n\n\
             {:<14} {:>8} {:>10} {:>10} {:>10}\n",
            self.events, self.traces, "phase", "count", "p50_us", "p99_us", "max_us"
        );
        for p in &self.phases {
            out.push_str(&format!(
                "{:<14} {:>8} {:>10} {:>10} {:>10}\n",
                p.name, p.count, p.p50_us, p.p99_us, p.max_us
            ));
        }
        if !self.slowest.is_empty() {
            out.push_str(&format!("\nslowest {} trace(s):\n", self.slowest.len()));
        }
        for t in &self.slowest {
            let breakdown = t
                .phases
                .iter()
                .map(|(n, d)| format!("{n} {d}"))
                .collect::<Vec<_>>()
                .join("  ");
            out.push_str(&format!(
                "  trace {} req {} conn {}: {}us ({breakdown}) \
                 worker {} width {} cycles {} energy_fj {}{}\n",
                t.trace_id,
                t.request_id,
                t.conn,
                t.total_us,
                t.worker,
                t.batch,
                t.cycles,
                t.energy_fj,
                if t.ok { "" } else { " ERR" },
            ));
        }
        out
    }

    fn to_json(&self) -> String {
        let phases = self
            .phases
            .iter()
            .map(|p| {
                format!(
                    "{{\"name\":\"{}\",\"count\":{},\"p50_us\":{},\"p99_us\":{},\"max_us\":{}}}",
                    p.name, p.count, p.p50_us, p.p99_us, p.max_us
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        let slowest = self
            .slowest
            .iter()
            .map(|t| {
                let breakdown = t
                    .phases
                    .iter()
                    .map(|(n, d)| format!("\"{n}\":{d}"))
                    .collect::<Vec<_>>()
                    .join(",");
                format!(
                    "{{\"trace\":{},\"req\":{},\"conn\":{},\"total_us\":{},\
                     \"phases\":{{{breakdown}}},\"worker\":{},\"batch\":{},\
                     \"cycles\":{},\"energy_fj\":{},\"ok\":{}}}",
                    t.trace_id,
                    t.request_id,
                    t.conn,
                    t.total_us,
                    t.worker,
                    t.batch,
                    t.cycles,
                    t.energy_fj,
                    t.ok
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"events\":{},\"traces\":{},\"phases\":[{phases}],\"slowest\":[{slowest}]}}",
            self.events, self.traces
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use impulse::obs::json::JsonValue;

    fn ev(name: &str, trace_id: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            ph: "X".to_string(),
            dur,
            trace_id,
            request_id: trace_id + 10,
            conn: 1,
            ..TraceEvent::default()
        }
    }

    fn lifecycle(trace_id: u64, scale: u64) -> Vec<TraceEvent> {
        let mut out: Vec<TraceEvent> = Phase::LIFECYCLE
            .iter()
            .enumerate()
            .map(|(i, p)| ev(p.name(), trace_id, (i as u64 + 1) * scale))
            .collect();
        let x = out.iter_mut().find(|e| e.name == "execute").unwrap();
        x.worker = 2;
        x.batch = 4;
        x.cycles = 100 * scale;
        x.energy_fj = 7 * scale;
        out
    }

    #[test]
    fn summarize_rolls_up_phases_and_ranks_traces() {
        let mut events = lifecycle(1, 1);
        events.extend(lifecycle(2, 10));
        let s = summarize(&events, 1);
        assert_eq!(s.events, 10);
        assert_eq!(s.traces, 2);
        assert_eq!(s.phases.len(), 5, "five lifecycle phases");
        assert_eq!(s.phases[0].name, "decode", "lifecycle display order");
        assert_eq!(s.phases[3].name, "execute");
        assert_eq!(s.phases[3].count, 2);
        assert_eq!(s.phases[3].max_us, 40);
        assert_eq!(s.slowest.len(), 1, "--slowest truncates");
        let t = &s.slowest[0];
        assert_eq!(t.trace_id, 2, "slowest trace wins");
        assert_eq!(t.total_us, (1 + 2 + 3 + 4 + 5) * 10);
        assert_eq!(t.cycles, 1000);
        assert_eq!(t.energy_fj, 70);
        assert_eq!(t.worker, 2);
        assert!(t.ok);
    }

    #[test]
    fn percentiles_use_rounded_rank() {
        let sorted: Vec<u64> = (1..=100).collect();
        assert_eq!(pct(&sorted, 50), 51);
        assert_eq!(pct(&sorted, 99), 99);
        assert_eq!(pct(&sorted, 100), 100);
        assert_eq!(pct(&[], 50), 0);
        assert_eq!(pct(&[7], 99), 7);
    }

    #[test]
    fn json_output_parses_and_carries_the_rollup() {
        let s = summarize(&lifecycle(9, 3), 5);
        let doc = JsonValue::parse(&s.to_json()).expect("summary JSON must parse");
        assert_eq!(doc.get("traces").and_then(JsonValue::as_u64), Some(1));
        let slow = doc.get("slowest").and_then(JsonValue::as_arr).unwrap();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].get("trace").and_then(JsonValue::as_u64), Some(9));
        assert_eq!(
            slow[0].get("phases").and_then(|p| p.get("execute")).and_then(JsonValue::as_u64),
            Some(12)
        );
    }

    #[test]
    fn failed_phases_mark_the_trace() {
        let mut events = lifecycle(3, 1);
        events[3].ok = false;
        let s = summarize(&events, 5);
        assert!(!s.slowest[0].ok);
        assert!(s.render("d").contains(" ERR"));
    }
}
