//! `impulse loadgen` — drive a scripted traffic scenario at a live
//! server and assert its latency/throughput/error envelope.
//!
//! The scenario is a builtin name (`smoke`, `burst`, `ramp`, `mixed`,
//! `stream`, `slowloris`, `fuzz`) or a path to a TOML scenario file
//! (`docs/REPLAY.md` documents the format). The target server is any
//! running `impulse serve --listen` instance; the envelope's p99 check
//! reads the server's own `StatsRequest` telemetry, as a delta across
//! the run. Exits nonzero when the envelope is violated.
//!
//! `--trace-dir <dir>` records one client-side span per operation
//! (submit → answer wall time, as the generator observed it) to a
//! Chrome trace-event JSON file in `<dir>` — line these up against a
//! server traced with `impulse serve --trace-dir` to see where
//! client-observed latency goes (`docs/OBSERVABILITY.md`).
//!
//! `--chaos kill|stall|blackhole` schedules one mid-run fault
//! (`docs/PROXY.md`): `stall` and `blackhole` degrade the traffic
//! path through an interposed relay from `--chaos-after-ms` (default
//! 500) for `--chaos-for-ms` (default 1000); `kill` sends `kill -9`
//! to `--chaos-kill-pid` — typically one backend behind an
//! `impulse proxy`, so the envelope asserts failover.

use impulse::obs::trace::{write_rotation, TraceRecorder};
use impulse::replay::loadgen::{
    run_scenario_chaos, ChaosMode, ChaosSpec, Scenario, BUILTIN_SCENARIOS,
};
use impulse::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

pub fn run(args: &[String]) -> Result<()> {
    let flags = super::Flags::parse(args);
    impulse::obs::log::init(flags.get("log-level"));
    let which = args.first().filter(|a| !a.starts_with("--")).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: impulse loadgen <scenario> --addr HOST:PORT\n  builtin scenarios: {}",
            BUILTIN_SCENARIOS.join(", ")
        )
    })?;
    let addr = flags.get("addr").unwrap_or("127.0.0.1:7878");
    let scenario = match Scenario::builtin(which) {
        Some(s) => s,
        None if Path::new(which).exists() => Scenario::from_file(Path::new(which))?,
        None => anyhow::bail!(
            "unknown scenario '{which}' (builtins: {}; or pass a scenario TOML path)",
            BUILTIN_SCENARIOS.join(", ")
        ),
    };
    impulse::info!(
        "loadgen",
        "scenario '{}' (seed {}) against {addr}: {} conn × {} req, \
         {} stream(s)/conn × {} append(s), mix_digits {:.2}, ramp {}ms, \
         {} slow-loris, {} fuzz frame(s)",
        scenario.name,
        scenario.seed,
        scenario.connections,
        scenario.requests_per_conn,
        scenario.streams_per_conn,
        scenario.appends_per_stream,
        scenario.mix_digits,
        scenario.ramp_ms,
        scenario.slow_loris,
        scenario.fuzz_frames,
    );
    let chaos = match flags.get("chaos") {
        None => None,
        Some(which) => {
            let mode = match which {
                "kill" => {
                    let pid = flags.get_usize("chaos-kill-pid").ok_or_else(|| {
                        anyhow::anyhow!("--chaos kill requires --chaos-kill-pid <pid>")
                    })?;
                    ChaosMode::Kill { pid: pid as u32 }
                }
                "stall" => ChaosMode::Stall,
                "blackhole" => ChaosMode::Blackhole,
                other => {
                    anyhow::bail!("unknown --chaos '{other}' (kill|stall|blackhole)")
                }
            };
            let after = flags.get_usize("chaos-after-ms").unwrap_or(500) as u64;
            let duration = flags.get_usize("chaos-for-ms").unwrap_or(1000) as u64;
            impulse::info!(
                "loadgen",
                "chaos: {mode:?} at +{after}ms{}",
                if matches!(mode, ChaosMode::Kill { .. }) {
                    String::new()
                } else {
                    format!(" for {duration}ms (path via interposed relay)")
                }
            );
            Some(ChaosSpec {
                mode,
                after: Duration::from_millis(after),
                duration: Duration::from_millis(duration),
            })
        }
    };
    let trace_dir = flags.get("trace-dir").map(PathBuf::from);
    let trace = trace_dir.as_ref().map(|_| Arc::new(TraceRecorder::new()));
    let report = run_scenario_chaos(addr, &scenario, trace.clone(), chaos)?;
    if let (Some(dir), Some(tr)) = (&trace_dir, &trace) {
        let spans = tr.drain();
        let path = write_rotation(dir, 0, &spans)?;
        impulse::info!(
            "loadgen",
            "wrote {} client span(s) to {} (inspect with `impulse trace {}`)",
            spans.len(),
            path.display(),
            dir.display()
        );
    }
    println!(
        "loadgen '{}': {} ok, {} error frame(s), {} transport error(s); \
         error rate {:.3}, p99 {}us, {:.1} op/s",
        scenario.name,
        report.ok,
        report.errors,
        report.transport_errors,
        report.error_rate(),
        report.p99_us,
        report.throughput_rps,
    );
    if report.is_ok() {
        println!(
            "envelope OK (min_ok {}, max_error_rate {:.3}{})",
            scenario.envelope.min_ok,
            scenario.envelope.max_error_rate,
            if scenario.envelope.max_p99_us > 0 {
                format!(", max_p99 {}us", scenario.envelope.max_p99_us)
            } else {
                String::new()
            }
        );
        Ok(())
    } else {
        anyhow::bail!("envelope VIOLATED:\n  {}", report.violations.join("\n  "))
    }
}
