//! `impulse stats <addr>` — fetch and print a server's live telemetry.
//!
//! Connects to a running `impulse serve --listen` instance over the
//! binary frame protocol, negotiates the backpressure capability,
//! sends one `StatsRequest` (`0x14`), and renders the `StatsResponse`
//! (`0x15`) snapshot: per-workload request/energy/EDP counters,
//! observed input sparsity, instruction issue, batch-lane occupancy,
//! per-transport latency, and the live backpressure advertisement from
//! the response frame's flags word.

use super::Flags;
use impulse::metrics::eng;
use impulse::serve::{decode_backpressure, FrameClient, CAP_BACKPRESSURE};
use impulse::telemetry::{instr_from_code, instr_name, kind_name, StatsSnapshot};
use impulse::Result;
use std::time::Duration;

/// The first positional (non-flag) argument, skipping each `--key`
/// together with the value token it consumed.
fn positional(args: &[String]) -> Option<&String> {
    let mut i = 0;
    while i < args.len() {
        if args[i].starts_with("--") {
            // skip the flag's value, if it has one (mirrors Flags::parse)
            if args.get(i + 1).is_some_and(|v| !v.starts_with("--")) {
                i += 1;
            }
        } else {
            return Some(&args[i]);
        }
        i += 1;
    }
    None
}

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    let addr = positional(args)
        .ok_or_else(|| anyhow::anyhow!("usage: impulse stats <addr> (e.g. 127.0.0.1:7878)"))?;
    let timeout = Duration::from_secs_f64(flags.get_f64("timeout-s").unwrap_or(10.0));

    let mut client = FrameClient::connect(addr.as_str())?;
    client.set_read_timeout(Some(timeout))?;
    let (version, caps) = client.hello_with_caps(CAP_BACKPRESSURE)?;
    let (snap, frame_flags) = client.stats()?;
    client.finish_writes().ok();

    println!("impulse stats — tcp://{addr} (protocol v{version}, caps {caps:#04x})");
    print_snapshot(&snap, frame_flags);
    Ok(())
}

/// Render a snapshot (and the response frame's flags word) for humans.
fn print_snapshot(s: &StatsSnapshot, frame_flags: u16) {
    let live = match decode_backpressure(frame_flags) {
        Some(bp) => format!(
            " [frame flags: depth {}, {}]",
            bp.queue_depth,
            if bp.soft_limited { "SOFT-LIMITED" } else { "clear" }
        ),
        None => String::new(),
    };
    println!(
        "queue: depth {} / soft limit {} (backpressure: {}){live}",
        s.queue_depth,
        s.queue_soft_limit,
        if s.soft_limited { "SIGNALLED" } else { "clear" },
    );
    println!(
        "batches: {} ({:.2} lanes occupied on average, {} of {} lane-slots used)",
        s.batches, s.mean_batch_occupancy(), s.batch_lanes, s.batch_lane_capacity,
    );
    for k in &s.kinds {
        if k.submitted == 0 && k.ok == 0 && k.err == 0 {
            continue;
        }
        println!(
            "workload {}: submitted {}, ok {}, err {}",
            kind_name(k.kind),
            k.submitted,
            k.ok,
            k.err
        );
        println!(
            "  cycles {}, energy {}, EDP {}",
            k.cycles,
            eng(k.energy_fj as f64 * 1e-15, "J"),
            eng(k.edp_js, "J·s"),
        );
        if k.input_units > 0 {
            println!(
                "  input sparsity {:.1}% ({} of {} units active)",
                k.input_sparsity() * 100.0,
                k.input_active,
                k.input_units,
            );
        }
    }
    let issued: Vec<String> = s
        .instr
        .iter()
        .filter(|(_, n)| *n > 0)
        .map(|&(code, n)| {
            let label = instr_from_code(code).map(instr_name).unwrap_or("unknown");
            format!("{label} {n}")
        })
        .collect();
    if !issued.is_empty() {
        println!("instructions: {}", issued.join(", "));
    }
    for t in &s.transports {
        if t.count == 0 {
            continue;
        }
        println!(
            "transport {}: {} served, mean {}, p50 ≤ {}, p99 ≤ {}",
            t.transport.name(),
            t.count,
            eng(t.sum_us as f64 / t.count as f64 * 1e-6, "s"),
            eng(t.quantile_us(0.5) as f64 * 1e-6, "s"),
            eng(t.quantile_us(0.99) as f64 * 1e-6, "s"),
        );
    }
}
