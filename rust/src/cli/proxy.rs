//! `impulse proxy` — the fault-tolerant front tier.
//!
//! Speaks the binary frame protocol of `docs/PROTOCOL.md` on both
//! sides: clients point at `--listen` exactly as they would at a
//! single `impulse serve --listen` backend, and the proxy routes over
//! the `--backend` fleet — least-loaded for one-shots, pinned for
//! streaming sessions, with active health checks, transparent
//! re-submission of idempotent work when a backend dies, and honest
//! `BackendLost` errors when recovery is impossible. Full semantics
//! in `docs/PROXY.md`.
//!
//! `--metrics-listen` serves the backends' per-fleet counters
//! (`impulse_proxy_*`) alongside the standard registry page;
//! `--trace-dir` records one `proxy_hop` span per request
//! (accepted → relayed) as Chrome trace rotations.

use super::Flags;
use impulse::obs::trace::{TraceFlusher, TraceRecorder};
use impulse::proxy::{serve_proxy, ProxyCore, ProxyOptions, ProxyServeHandle};
use impulse::serve::install_shutdown_handler;
use impulse::telemetry::{serve_metrics_with, Telemetry};
use impulse::Result;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

pub fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args);
    if let Some(l) = flags.get("log-level") {
        anyhow::ensure!(
            impulse::obs::log::parse_level(l).is_some(),
            "unknown --log-level '{l}' (error|warn|info|debug)"
        );
    }
    impulse::obs::log::init(flags.get("log-level"));
    let listen = flags
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("impulse proxy requires --listen <addr>"))?
        .to_string();
    let backends: Vec<String> =
        flags.get_all("backend").into_iter().map(str::to_string).collect();
    anyhow::ensure!(
        !backends.is_empty(),
        "impulse proxy requires at least one --backend <addr> (repeatable)"
    );

    let mut opts = ProxyOptions::new(backends);
    if let Some(ms) = flags.get_usize("health-interval-ms") {
        opts.health_interval = Duration::from_millis((ms as u64).max(1));
    }
    if let Some(ms) = flags.get_usize("health-timeout-ms") {
        opts.health_timeout = Duration::from_millis((ms as u64).max(1));
    }
    if let Some(n) = flags.get_usize("retry-max") {
        opts.retry_max = n as u32;
    }
    if let Some(ms) = flags.get_usize("request-deadline-ms") {
        opts.request_deadline = Duration::from_millis((ms as u64).max(1));
    }
    if let Some(ms) = flags.get_usize("reconnect-base-ms") {
        opts.reconnect_base = Duration::from_millis((ms as u64).max(1));
    }

    // --trace-dir <dir>: one proxy_hop span per request (accepted →
    // response relayed); inspect with `impulse trace <dir>`
    let trace_flusher = match flags.get("trace-dir") {
        Some(dir) => {
            let rec = Arc::new(TraceRecorder::new());
            opts.trace = Some(Arc::clone(&rec));
            impulse::info!(
                "proxy",
                "tracing proxy hops to {dir} (inspect with `impulse trace {dir}`)"
            );
            Some(TraceFlusher::start(rec, PathBuf::from(dir)))
        }
        None => None,
    };

    let core = ProxyCore::start(opts)?;

    // the proxy has no local inference registry; its metrics page is
    // the (empty) standard pages plus the per-backend fleet counters
    let metrics = match flags.get("metrics-listen") {
        Some(addr) => {
            let page_core = Arc::clone(&core);
            let h = serve_metrics_with(
                addr,
                Arc::new(Telemetry::default()),
                Arc::new(move || page_core.stats().to_prometheus()),
            )?;
            impulse::info!(
                "proxy",
                "metrics (Prometheus text) on http://{}/metrics (liveness on /healthz)",
                h.local_addr()
            );
            Some(h)
        }
        None => None,
    };

    let handle = serve_proxy(&listen, Arc::clone(&core))?;
    impulse::info!(
        "proxy",
        "proxying tcp://{} over {} backend(s): {}; \
         binary frame protocol v{} (docs/PROTOCOL.md, docs/PROXY.md); \
         SIGINT/SIGTERM drains and exits",
        handle.local_addr(),
        core.backend_addrs().len(),
        core.backend_addrs().join(", "),
        impulse::serve::PROTOCOL_VERSION,
    );
    serve_until_signalled(handle);

    if let Some(h) = metrics {
        h.stop();
    }
    core.shutdown();
    // stop tracing after shutdown so in-flight hops make the final
    // rotation
    if let Some(f) = trace_flusher {
        f.stop();
    }
    Ok(())
}

/// Serve until SIGINT/SIGTERM arrives or the accept loop fails on its
/// own (the serve CLI's loop, retyped for the proxy's handle).
fn serve_until_signalled(handle: ProxyServeHandle) {
    let stop = install_shutdown_handler();
    while !stop.load(Ordering::SeqCst) && !handle.is_finished() {
        std::thread::sleep(Duration::from_millis(50));
    }
    if stop.load(Ordering::SeqCst) {
        impulse::info!("proxy", "shutdown signal — winding down…");
    }
    handle.stop();
    impulse::info!("proxy", "stopped");
}
