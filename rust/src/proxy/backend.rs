//! Per-backend upstream link state: the shared write half, the
//! in-flight table, and the reader thread that relays responses back
//! to their clients.
//!
//! Every client request that reaches a backend lives in exactly one
//! link's `pending` table while it is in flight, keyed by the
//! *upstream* request id the proxy assigned (see
//! [`ProxyCore::forward`]). The link's reader thread removes the
//! entry when the response arrives; [`ProxyCore::link_down`] drains
//! whatever is left when the link dies and decides, per entry,
//! between re-submission and an honest `BackendLost` answer.
//!
//! [`ProxyCore::forward`]: super::ProxyCore
//! [`ProxyCore::link_down`]: super::ProxyCore

use std::collections::{HashMap, HashSet};
use std::io::ErrorKind;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::serve::{decode_backpressure, Frame, FrameReader, PayloadType, WireError};

use super::ProxyCore;

/// What a proxied request is, for failover purposes. The split is the
/// heart of the proxy's honesty contract: only work whose re-execution
/// is observably identical to a first execution may be re-submitted
/// behind the client's back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    /// A stateless request (`InferRequest`, `DigitsInferRequest`,
    /// `StatsRequest`): idempotent, safe to transparently re-submit
    /// to a surviving backend if the routed one dies holding it.
    OneShot,
    /// A `StreamOpen`: pins its upstream request id (which becomes
    /// the stream id) to the chosen backend. Re-submittable while
    /// unacknowledged — no client-visible state existed yet.
    StreamOpen,
    /// An operation on an already-open stream (append, read-out,
    /// close). Never re-submitted: the membrane state it addresses
    /// lives on exactly one backend.
    StreamOp {
        /// The upstream stream id the operation addresses (the first
        /// 8 payload bytes).
        stream_id: u64,
    },
}

/// One proxied request while it is in flight to a backend.
pub struct ProxyPending {
    /// The request's payload type, replayed verbatim on re-submission.
    pub(crate) ty: PayloadType,
    /// The client's flags word, forwarded verbatim upstream (carries
    /// the telemetry/trace-echo request bits).
    pub(crate) flags: u16,
    /// The request payload, forwarded verbatim.
    pub(crate) payload: Vec<u8>,
    /// The request id the client used — responses are re-keyed back
    /// to it before relay.
    pub(crate) external_id: u64,
    /// The client connection to answer (`None` for proxy-initiated
    /// janitorial frames, e.g. closing streams of a vanished client).
    pub(crate) client: Option<ClientHandle>,
    /// Times this request has already been (re-)submitted.
    pub(crate) attempts: u32,
    /// Hard per-request deadline; re-submission never crosses it.
    pub(crate) deadline: Instant,
    /// When the proxy accepted the request (starts the proxy-hop span).
    pub(crate) enqueued: Instant,
    /// Failover classification.
    pub(crate) kind: ReqKind,
}

/// One client connection's shared write half plus the bookkeeping the
/// proxy needs to clean up after it: the connection id (for trace
/// spans) and the set of upstream stream ids it opened.
#[derive(Clone)]
pub struct ClientHandle {
    pub(crate) stream: Arc<Mutex<TcpStream>>,
    pub(crate) conn_id: u64,
    pub(crate) streams: Arc<Mutex<HashSet<u64>>>,
}

impl ClientHandle {
    /// Write one frame to the client. The mutex keeps frames
    /// contiguous on the wire — link readers and the client's own
    /// reader thread all answer through here.
    pub(crate) fn write(&self, f: &Frame) -> std::io::Result<()> {
        let mut g = self.stream.lock().expect("client writer poisoned");
        f.write_to(&mut *g)
    }
}

/// The proxy's upstream link to one backend. The lifecycle state and
/// in-flight gauge live in [`ProxyStats`] (single source of truth for
/// routing and the metrics page); this struct holds what the wire
/// needs: the socket, the pending table, and the freshest
/// backpressure advertisement.
///
/// `generation` increments each time a new connection is installed;
/// reader threads and death reports carry the generation they belong
/// to, so a stale report can never tear down a newer link.
///
/// [`ProxyStats`]: crate::telemetry::ProxyStats
pub struct BackendLink {
    /// The backend address, as given on the command line.
    pub addr: String,
    pub(crate) writer: Mutex<Option<TcpStream>>,
    pub(crate) pending: Mutex<HashMap<u64, ProxyPending>>,
    pub(crate) generation: AtomicU64,
    pub(crate) soft_limited: AtomicBool,
    pub(crate) depth: AtomicU64,
    pub(crate) health_fails: AtomicU32,
}

impl BackendLink {
    /// A link with no connection yet (state starts Down; the
    /// reconnect loop brings it up).
    pub(crate) fn new(addr: String) -> BackendLink {
        BackendLink {
            addr,
            writer: Mutex::new(None),
            pending: Mutex::new(HashMap::new()),
            generation: AtomicU64::new(0),
            soft_limited: AtomicBool::new(false),
            depth: AtomicU64::new(0),
            health_fails: AtomicU32::new(0),
        }
    }

    /// Fold a response frame's backpressure advertisement (if any)
    /// into the link's routing inputs.
    pub(crate) fn observe_flags(&self, flags: u16) {
        if let Some(bp) = decode_backpressure(flags) {
            self.depth.store(bp.queue_depth as u64, Ordering::Relaxed);
            self.soft_limited.store(bp.soft_limited, Ordering::Relaxed);
        }
    }

    /// Routing load estimate: our own in-flight count (precise, but
    /// blind to the backend's other clients) weighted double, plus
    /// the backend's advertised queue depth (global, but stale).
    pub(crate) fn load(&self, in_flight: u64) -> u64 {
        in_flight * 2 + self.depth.load(Ordering::Relaxed)
    }
}

/// The per-link reader thread body: relay upstream frames back to
/// their clients until the link dies, a newer generation replaces it,
/// or the proxy stops.
pub(crate) fn link_reader(
    core: Arc<ProxyCore>,
    idx: usize,
    generation: u64,
    mut reader: FrameReader<TcpStream>,
) {
    loop {
        if core.stopped() {
            return;
        }
        if core.links[idx].generation.load(Ordering::SeqCst) != generation {
            return; // a newer link owns this backend now
        }
        match reader.next_frame() {
            Ok(Some(f)) => core.on_upstream_frame(idx, f),
            Ok(None) => {
                core.link_down(idx, generation, "backend closed the connection");
                return;
            }
            Err(WireError::Io(e))
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                // read-timeout tick: partial frames survive in the
                // reader's carry buffer; loop to recheck stop/generation
            }
            Err(e) => {
                core.link_down(idx, generation, &format!("read failed: {e}"));
                return;
            }
        }
    }
}
