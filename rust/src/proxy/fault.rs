//! Fault injection: a byte-level TCP relay that can kill, stall, or
//! black-hole the path to a backend mid-run.
//!
//! Tests (and `impulse loadgen --chaos`) put a [`FaultRelay`] between
//! the proxy and a backend, drive traffic, then flip the fault mode —
//! so failover is exercised against the three failure shapes that
//! matter operationally:
//!
//! - **kill** — connections reset and the port stops answering, like
//!   `kill -9` on the backend: passive detection (link reader I/O
//!   error) fires immediately;
//! - **stall** — bytes stop being read, like a wedged process under
//!   an intact TCP session: nothing errors, kernel buffers fill;
//! - **black hole** — bytes are read and discarded, like a process
//!   looping with its threads parked: the connection looks perfectly
//!   healthy and only the active `StatsRequest` probe can tell.
//!
//! The relay is deliberately dumb — it never parses frames — so it
//! cannot mask protocol bugs.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::Result;

use super::resolve;

/// What the relay does with bytes in both directions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// Copy bytes through unmodified (healthy path).
    Pass,
    /// Stop reading entirely: the peer's writes eventually block
    /// (kernel buffers full) but nothing errors.
    Stall,
    /// Read and discard: both sides see a live, silent connection.
    Blackhole,
}

impl FaultMode {
    fn as_u8(self) -> u8 {
        match self {
            FaultMode::Pass => 0,
            FaultMode::Stall => 1,
            FaultMode::Blackhole => 2,
        }
    }

    fn from_u8(v: u8) -> FaultMode {
        match v {
            1 => FaultMode::Stall,
            2 => FaultMode::Blackhole,
            _ => FaultMode::Pass,
        }
    }
}

/// A running fault-injection relay in front of one target address.
pub struct FaultRelay {
    addr: SocketAddr,
    mode: Arc<AtomicU8>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl FaultRelay {
    /// Bind an ephemeral local port relaying to `target`, starting in
    /// [`FaultMode::Pass`]. Point the proxy's `--backend` (or a
    /// client's `--addr`) at [`FaultRelay::local_addr`].
    pub fn start(target: &str) -> Result<FaultRelay> {
        let target = resolve(target)?;
        let listener = TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let mode = Arc::new(AtomicU8::new(FaultMode::Pass.as_u8()));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let mode = Arc::clone(&mode);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || {
                loop {
                    if stop.load(Ordering::SeqCst) {
                        break; // drops the listener: the port stops answering
                    }
                    match listener.accept() {
                        Ok((client, _peer)) => {
                            let upstream = match TcpStream::connect_timeout(
                                &target,
                                Duration::from_secs(2),
                            ) {
                                Ok(u) => u,
                                Err(_) => {
                                    let _ = client.shutdown(Shutdown::Both);
                                    continue;
                                }
                            };
                            let _ = client.set_nonblocking(false);
                            track(&conns, &client);
                            track(&conns, &upstream);
                            spawn_pump(
                                client.try_clone(),
                                upstream.try_clone(),
                                Arc::clone(&mode),
                                Arc::clone(&stop),
                            );
                            spawn_pump(Ok(upstream), Ok(client), Arc::clone(&mode), Arc::clone(&stop));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })
        };
        Ok(FaultRelay { addr, mode, stop, conns, accept: Some(accept) })
    }

    /// The relay's client-facing address.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Switch fault modes; applies to live connections immediately.
    pub fn set_mode(&self, mode: FaultMode) {
        self.mode.store(mode.as_u8(), Ordering::SeqCst);
    }

    /// Simulate `kill -9`: reset every live connection and stop
    /// answering the port. Unlike [`FaultRelay::set_mode`] this is
    /// not reversible — like the process it imitates.
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let conns = std::mem::take(&mut *self.conns.lock().expect("relay conns poisoned"));
        for c in conns {
            let _ = c.shutdown(Shutdown::Both);
        }
    }

    /// Kill (if not already) and join the accept loop.
    pub fn stop(mut self) {
        self.kill();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultRelay {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// Remember a connection so [`FaultRelay::kill`] can reset it.
fn track(conns: &Arc<Mutex<Vec<TcpStream>>>, s: &TcpStream) {
    if let Ok(c) = s.try_clone() {
        conns.lock().expect("relay conns poisoned").push(c);
    }
}

/// One direction's pump thread: move bytes `from` → `to` per the
/// current fault mode until either side dies or the relay stops.
fn spawn_pump(
    from: std::io::Result<TcpStream>,
    to: std::io::Result<TcpStream>,
    mode: Arc<AtomicU8>,
    stop: Arc<AtomicBool>,
) {
    let (mut from, mut to) = match (from, to) {
        (Ok(f), Ok(t)) => (f, t),
        _ => return,
    };
    if from.set_read_timeout(Some(Duration::from_millis(50))).is_err() {
        return;
    }
    let _ = to.set_write_timeout(Some(Duration::from_secs(5)));
    std::thread::spawn(move || {
        let mut buf = [0u8; 4096];
        loop {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            match FaultMode::from_u8(mode.load(Ordering::SeqCst)) {
                FaultMode::Stall => {
                    // don't touch the socket: bytes pile up in kernel
                    // buffers exactly as behind a wedged process
                    std::thread::sleep(Duration::from_millis(50));
                    continue;
                }
                FaultMode::Pass | FaultMode::Blackhole => {}
            }
            match from.read(&mut buf) {
                Ok(0) => break, // peer closed
                Ok(n) => {
                    let discard =
                        FaultMode::from_u8(mode.load(Ordering::SeqCst)) == FaultMode::Blackhole;
                    if !discard && to.write_all(&buf[..n]).is_err() {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
                Err(_) => break,
            }
        }
        let _ = from.shutdown(Shutdown::Both);
        let _ = to.shutdown(Shutdown::Both);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A one-connection echo server for exercising the relay.
    fn echo_server() -> (SocketAddr, std::thread::JoinHandle<()>) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            if let Ok((mut s, _)) = l.accept() {
                let mut buf = [0u8; 256];
                while let Ok(n) = s.read(&mut buf) {
                    if n == 0 || s.write_all(&buf[..n]).is_err() {
                        break;
                    }
                }
            }
        });
        (addr, t)
    }

    #[test]
    fn pass_mode_relays_bytes_both_ways() {
        let (addr, server) = echo_server();
        let relay = FaultRelay::start(&addr.to_string()).unwrap();
        let mut c = TcpStream::connect(relay.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        c.write_all(b"spike").unwrap();
        let mut got = [0u8; 5];
        c.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"spike");
        drop(c);
        relay.stop();
        server.join().unwrap();
    }

    #[test]
    fn kill_resets_live_connections_and_refuses_new_ones() {
        let (addr, server) = echo_server();
        let relay = FaultRelay::start(&addr.to_string()).unwrap();
        let mut c = TcpStream::connect(relay.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        c.write_all(b"spike").unwrap();
        let mut got = [0u8; 5];
        c.read_exact(&mut got).unwrap();

        let dead_port = relay.local_addr();
        relay.kill();
        // the live connection dies: reads answer EOF or a reset
        let n = c.read(&mut got);
        assert!(matches!(n, Ok(0) | Err(_)), "killed relay must sever the connection: {n:?}");
        // and (within the accept loop's poll tick) new connects fail
        std::thread::sleep(Duration::from_millis(100));
        let again = TcpStream::connect_timeout(
            &dead_port,
            Duration::from_millis(500),
        );
        assert!(again.is_err(), "killed relay must stop answering its port");
        relay.stop();
        server.join().unwrap();
    }

    #[test]
    fn blackhole_swallows_bytes_without_erroring() {
        let (addr, server) = echo_server();
        let relay = FaultRelay::start(&addr.to_string()).unwrap();
        let mut c = TcpStream::connect(relay.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(300))).unwrap();
        relay.set_mode(FaultMode::Blackhole);
        std::thread::sleep(Duration::from_millis(60)); // let pumps see the mode
        c.write_all(b"spike").unwrap();
        let mut got = [0u8; 5];
        let r = c.read(&mut got);
        assert!(r.is_err(), "black-holed echo must never answer: {r:?}");
        relay.stop();
        server.join().unwrap();
    }
}
