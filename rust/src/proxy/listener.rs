//! The proxy's client-facing TCP accept loop.
//!
//! Each accepted connection gets a reader thread (the connection's
//! spawned thread) that decodes request frames, classifies them into
//! [`ReqKind`]s, and hands them to [`ProxyCore::submit`]. There is no
//! per-connection responder thread: responses are written by the
//! per-*backend* link readers straight through the connection's
//! shared [`ClientHandle`] — the same shared-write-half discipline
//! the serve listener's `ConnWriter` uses.
//!
//! Hello negotiation is answered *locally* (the proxy is the client's
//! protocol peer); the upstream links run their own hello with both
//! capability bits, and response flags are relayed verbatim, so a
//! client that negotiated backpressure or trace-echo sees exactly
//! what the backend stamped.
//!
//! [`ReqKind`]: super::backend::ReqKind
//! [`ProxyCore::submit`]: super::ProxyCore
//! [`ClientHandle`]: super::backend::ClientHandle

use std::collections::HashSet;
use std::io::ErrorKind;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::serve::{
    error_frame, negotiate, ErrorCode, Frame, FrameReader, PayloadType, WireError,
    PROTOCOL_VERSION,
};
use crate::Result;

use super::backend::{ClientHandle, ProxyPending, ReqKind};
use super::{ProxyCore, POLL, WRITE_TIMEOUT};

/// A running proxy front-end (accept loop + client connections).
pub struct ProxyServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ProxyServeHandle {
    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop and all client connections to wind
    /// down, then join them.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Whether the accept loop has already exited — lets a supervisor
    /// poll without blocking, as the CLI's signal loop does.
    pub fn is_finished(&self) -> bool {
        self.accept.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }
}

/// Bind `addr` (port `0` for ephemeral) and serve framed requests
/// over the proxy core until [`ProxyServeHandle::stop`].
pub fn serve_proxy(addr: &str, core: Arc<ProxyCore>) -> Result<ProxyServeHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let accept = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            loop {
                if stop.load(Ordering::SeqCst) || core.stopped() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let core = Arc::clone(&core);
                        let stop = Arc::clone(&stop);
                        conns.push(std::thread::spawn(move || {
                            handle_conn(stream, &core, &stop);
                        }));
                        conns.retain(|h| !h.is_finished());
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    Err(e) => {
                        crate::error!("proxy", "accept failed: {e}");
                        break;
                    }
                }
            }
            for c in conns {
                let _ = c.join();
            }
        })
    };
    Ok(ProxyServeHandle { addr: local, stop, accept: Some(accept) })
}

/// Drive one client connection: read frames until EOF, a framing
/// error, or stop; then close whatever streams it still has pinned.
fn handle_conn(stream: TcpStream, core: &Arc<ProxyCore>, stop: &Arc<AtomicBool>) {
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(POLL)).is_err()
        || stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err()
    {
        return;
    }
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let conn_id = core.next_conn_id();
    let handle = ClientHandle {
        stream: Arc::new(Mutex::new(writer)),
        conn_id,
        streams: Arc::new(Mutex::new(HashSet::new())),
    };
    let mut reader = FrameReader::new(stream);
    let mut negotiated = PROTOCOL_VERSION; // implicit v1 until Hello
    loop {
        if stop.load(Ordering::SeqCst) || core.stopped() {
            break;
        }
        let frame = match reader.next_frame() {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean EOF
            Err(WireError::Io(e))
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => {
                // alignment is lost; answer once (request id 0) and close
                let _ = handle.write(&error_frame(0, e.code(), &e.to_string()));
                break;
            }
        };
        match frame.payload_type {
            PayloadType::Hello => match negotiate(&frame.payload) {
                Ok(n) => {
                    negotiated = n.version;
                    // grant locally: the upstream links negotiated both
                    // capabilities, so whatever subset the client asked
                    // for flows through end to end
                    let ack_payload = if frame.payload.len() == 3 {
                        vec![n.version, n.caps]
                    } else {
                        vec![n.version]
                    };
                    let ack = Frame::new(PayloadType::HelloAck, frame.request_id, ack_payload);
                    if handle.write(&ack).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = handle.write(&error_frame(frame.request_id, e.code, &e.msg));
                    break; // failed negotiation closes the connection
                }
            },
            PayloadType::InferRequest
            | PayloadType::DigitsInferRequest
            | PayloadType::StatsRequest
            | PayloadType::StreamOpen
            | PayloadType::StreamAppend
            | PayloadType::StreamReadOut
            | PayloadType::StreamClose => {
                if frame.version != negotiated {
                    let msg =
                        format!("frame version {} after negotiating v{negotiated}", frame.version);
                    let _ = handle.write(&error_frame(
                        frame.request_id,
                        ErrorCode::UnsupportedVersion,
                        &msg,
                    ));
                    continue;
                }
                let kind = match classify(&frame) {
                    Ok(kind) => kind,
                    Err(msg) => {
                        // local decode error: the payload cannot even be
                        // routed; the connection stays up
                        let _ = handle.write(&error_frame(
                            frame.request_id,
                            ErrorCode::Malformed,
                            &msg,
                        ));
                        continue;
                    }
                };
                let now = Instant::now();
                core.submit(ProxyPending {
                    ty: frame.payload_type,
                    flags: frame.flags,
                    payload: frame.payload,
                    external_id: frame.request_id,
                    client: Some(handle.clone()),
                    attempts: 0,
                    deadline: now + core.opts.request_deadline,
                    enqueued: now,
                    kind,
                });
            }
            // server→client types are invalid from a client
            PayloadType::HelloAck
            | PayloadType::InferResponse
            | PayloadType::DigitsInferResponse
            | PayloadType::StatsResponse
            | PayloadType::StreamAck
            | PayloadType::Error => {
                let _ = handle.write(&error_frame(
                    frame.request_id,
                    ErrorCode::Malformed,
                    &format!("{:?} frames are server-to-client only", frame.payload_type),
                ));
            }
        }
    }
    // a vanished client releases its pinned backend lanes — the proxy
    // closes them upstream so no stream outlives its transport
    let open: Vec<u64> = {
        let g = handle.streams.lock().expect("stream set poisoned");
        g.iter().copied().collect()
    };
    core.close_client_streams(open);
    if let Ok(g) = handle.stream.lock() {
        let _ = g.shutdown(Shutdown::Write);
    }
}

/// Classify a routable frame into its failover kind, extracting the
/// stream id stream operations are pinned by.
fn classify(frame: &Frame) -> std::result::Result<ReqKind, String> {
    match frame.payload_type {
        PayloadType::InferRequest | PayloadType::DigitsInferRequest | PayloadType::StatsRequest => {
            Ok(ReqKind::OneShot)
        }
        PayloadType::StreamOpen => Ok(ReqKind::StreamOpen),
        PayloadType::StreamAppend => {
            // append payload: stream_id u64 BE + kind byte + chunk
            if frame.payload.len() < 9 {
                return Err(format!(
                    "stream append payload must be at least 9 bytes, got {}",
                    frame.payload.len()
                ));
            }
            Ok(ReqKind::StreamOp { stream_id: be_u64(&frame.payload[..8]) })
        }
        PayloadType::StreamReadOut | PayloadType::StreamClose => {
            if frame.payload.len() != 8 {
                return Err(format!(
                    "stream ref payload must be 8 bytes, got {}",
                    frame.payload.len()
                ));
            }
            Ok(ReqKind::StreamOp { stream_id: be_u64(&frame.payload[..8]) })
        }
        other => Err(format!("{other:?} is not routable")),
    }
}

/// Big-endian u64 from an 8-byte slice.
fn be_u64(b: &[u8]) -> u64 {
    u64::from_be_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(ty: PayloadType, payload: Vec<u8>) -> Frame {
        Frame::new(ty, 7, payload)
    }

    #[test]
    fn classify_splits_one_shots_from_stream_ops() {
        assert_eq!(
            classify(&frame(PayloadType::InferRequest, vec![0, 1])),
            Ok(ReqKind::OneShot)
        );
        assert_eq!(classify(&frame(PayloadType::StatsRequest, vec![])), Ok(ReqKind::OneShot));
        assert_eq!(classify(&frame(PayloadType::StreamOpen, vec![])), Ok(ReqKind::StreamOpen));
    }

    #[test]
    fn classify_extracts_the_pinning_stream_id() {
        let mut append = 42u64.to_be_bytes().to_vec();
        append.push(0); // kind byte
        append.push(9); // one chunk byte
        assert_eq!(
            classify(&frame(PayloadType::StreamAppend, append)),
            Ok(ReqKind::StreamOp { stream_id: 42 })
        );
        let close = 42u64.to_be_bytes().to_vec();
        assert_eq!(
            classify(&frame(PayloadType::StreamClose, close)),
            Ok(ReqKind::StreamOp { stream_id: 42 })
        );
    }

    #[test]
    fn classify_rejects_undersized_stream_payloads() {
        assert!(classify(&frame(PayloadType::StreamAppend, vec![1, 2, 3])).is_err());
        assert!(classify(&frame(PayloadType::StreamReadOut, vec![1, 2, 3])).is_err());
        assert!(classify(&frame(PayloadType::Error, vec![])).is_err());
    }
}
