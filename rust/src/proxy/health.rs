//! Active health checking: `StatsRequest` probes on fresh
//! connections, on a configurable interval.
//!
//! The per-link reader threads already provide *passive* health — an
//! I/O error on the link reports the backend down immediately. The
//! active prober covers what passive detection cannot see:
//!
//! - a backend that accepts bytes but stopped answering (black-holed
//!   or wedged): the link reader just waits forever, the probe times
//!   out;
//! - recovery of a `Draining` backend — nothing else ever promotes it
//!   back to `Up`.
//!
//! Probes use a *fresh* connection per probe rather than riding the
//! request link, so a probe exercises the full accept → hello →
//! answer path (the same thing a new client would experience) and a
//! wedged request link cannot make a healthy backend look alive.
//!
//! State machine (per backend): one failed probe demotes `Up` to
//! `Draining` (finishes in-flight work, sheds new work to peers);
//! [`HEALTH_FAILS_TO_DOWN`] consecutive failures declare it `Down`
//! outright, which drains its in-flight table through the normal
//! failover path. A probe success resets the failure count and
//! promotes `Draining` back to `Up`. `Down` backends are skipped —
//! the reconnect loop owns their recovery.

use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use crate::serve::{
    encode_stats_request, hello_payload, Frame, FrameReader, PayloadType, PROTOCOL_VERSION,
};
use crate::telemetry::{BACKEND_DOWN, BACKEND_DRAINING, BACKEND_UP};
use crate::Result;

use super::{resolve, sleep_while_running, ProxyCore};

/// Consecutive probe failures before a backend is declared `Down`
/// outright (covers black-holed links whose reader never errors).
pub const HEALTH_FAILS_TO_DOWN: u32 = 2;

/// The health thread body: probe every non-`Down` backend each
/// interval until the proxy stops.
pub(crate) fn health_loop(core: Arc<ProxyCore>) {
    loop {
        if !sleep_while_running(&core, core.opts.health_interval) {
            return;
        }
        for idx in 0..core.links.len() {
            if core.stopped() {
                return;
            }
            let state = core.stats().state(idx);
            if state == BACKEND_DOWN {
                continue; // the reconnect loop owns recovery
            }
            let link = &core.links[idx];
            match probe(&link.addr, core.opts.health_timeout) {
                Ok(()) => {
                    link.health_fails.store(0, Ordering::SeqCst);
                    if core.stats().transition(idx, BACKEND_DRAINING, BACKEND_UP) {
                        crate::info!("proxy", "backend {} answers again; back up", link.addr);
                    }
                }
                Err(e) => {
                    core.stats().record_health_failure(idx);
                    let fails = link.health_fails.fetch_add(1, Ordering::SeqCst) + 1;
                    crate::warn!(
                        "proxy",
                        "backend {} failed health probe ({fails} consecutive): {e:#}",
                        link.addr
                    );
                    if fails >= HEALTH_FAILS_TO_DOWN {
                        // repeated failure: declare it dead even if the
                        // request link never errored (black hole) — the
                        // generation guard makes a stale report harmless
                        let generation = link.generation.load(Ordering::SeqCst);
                        core.link_down(
                            idx,
                            generation,
                            &format!("{fails} consecutive health probes failed"),
                        );
                    } else {
                        // first strike: stop routing new work its way,
                        // let in-flight work finish (never resurrects a
                        // concurrently-declared-Down backend)
                        core.stats().transition(idx, BACKEND_UP, BACKEND_DRAINING);
                    }
                }
            }
        }
    }
}

/// One active probe: fresh TCP connection, v1 hello, one
/// `StatsRequest` answered within `timeout`. Public so the CLI (and
/// tests) can reuse it as a backend readiness check.
pub fn probe(addr: &str, timeout: Duration) -> Result<()> {
    let sa = resolve(addr)?;
    let stream = TcpStream::connect_timeout(&sa, timeout)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut w = stream.try_clone()?;
    let mut reader = FrameReader::new(stream);
    Frame::new(PayloadType::Hello, 0, hello_payload(PROTOCOL_VERSION, PROTOCOL_VERSION))
        .write_to(&mut w)?;
    expect(&mut reader, PayloadType::HelloAck)?;
    Frame::new(PayloadType::StatsRequest, 1, encode_stats_request()).write_to(&mut w)?;
    expect(&mut reader, PayloadType::StatsResponse)?;
    Ok(())
}

/// Read one frame and require it to be of type `want`.
fn expect(reader: &mut FrameReader<TcpStream>, want: PayloadType) -> Result<()> {
    match reader.next_frame() {
        Ok(Some(f)) if f.payload_type == want => Ok(()),
        Ok(Some(f)) => anyhow::bail!("expected {want:?}, got {:?}", f.payload_type),
        Ok(None) => anyhow::bail!("connection closed awaiting {want:?}"),
        Err(e) => anyhow::bail!("awaiting {want:?}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_fails_fast_against_a_closed_port() {
        // bind-then-drop guarantees an unserved port
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        assert!(probe(&addr, Duration::from_millis(250)).is_err());
    }

    #[test]
    fn probe_times_out_against_a_silent_listener() {
        // accepts but never answers: the hello-ack read must time out
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let _conn = l.accept();
            std::thread::sleep(Duration::from_millis(600));
        });
        let err = probe(&addr, Duration::from_millis(150));
        assert!(err.is_err(), "silent listener must fail the probe");
        t.join().unwrap();
    }
}
