//! Routing policy: least-loaded within health-tiered preference.
//!
//! New (un-pinned) requests go to the least-loaded backend of the
//! best available health tier:
//!
//! 1. `Up` and not soft-limited — healthy, unconstrained;
//! 2. `Up` but advertising the soft limit — answering, asked us to
//!    slow down;
//! 3. `Draining` — suspect (one failed health probe), finishes what
//!    it has, takes new work only when every peer is worse.
//!
//! `Down` backends are never routable. Stream-pinned requests bypass
//! this entirely — the pin map in [`ProxyCore`] owns them.
//!
//! [`ProxyCore`]: super::ProxyCore

use std::sync::atomic::Ordering;

use crate::telemetry::{ProxyStats, BACKEND_DOWN, BACKEND_DRAINING, BACKEND_UP};

use super::backend::BackendLink;

/// The health tier a backend routes in right now (lower is better),
/// or `None` when it is not routable at all.
fn tier(link: &BackendLink, state: u8) -> Option<u8> {
    match state {
        BACKEND_UP if !link.soft_limited.load(Ordering::Relaxed) => Some(0),
        BACKEND_UP => Some(1),
        BACKEND_DRAINING => Some(2),
        _ => None, // BACKEND_DOWN
    }
}

/// Pick the backend index for a new request, or `None` when every
/// backend is down. Records a spill against each constrained backend
/// that plain least-loaded routing would have chosen (equal-or-lower
/// load, worse tier) — the observable trace of soft-limit shedding.
pub fn pick_backend(links: &[BackendLink], stats: &ProxyStats) -> Option<usize> {
    let mut best: Option<(u8, u64, usize)> = None;
    for (idx, link) in links.iter().enumerate() {
        let Some(t) = tier(link, stats.state(idx)) else { continue };
        let load = link.load(stats.in_flight(idx));
        let better = match best {
            None => true,
            Some((bt, bl, _)) => (t, load) < (bt, bl),
        };
        if better {
            best = Some((t, load, idx));
        }
    }
    let (pick_tier, pick_load, pick) = best?;
    for (idx, link) in links.iter().enumerate() {
        if idx == pick {
            continue;
        }
        let Some(t) = tier(link, stats.state(idx)) else { continue };
        if t > pick_tier && link.load(stats.in_flight(idx)) <= pick_load {
            stats.record_spill(idx);
        }
    }
    Some(pick)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> (Vec<BackendLink>, ProxyStats) {
        let addrs: Vec<String> = (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect();
        let links = addrs.iter().map(|a| BackendLink::new(a.clone())).collect();
        (links, ProxyStats::new(&addrs))
    }

    #[test]
    fn all_down_routes_nowhere() {
        let (links, stats) = fleet(2);
        assert_eq!(pick_backend(&links, &stats), None);
    }

    #[test]
    fn least_loaded_wins_within_the_healthy_tier() {
        let (links, stats) = fleet(3);
        for i in 0..3 {
            stats.set_state(i, BACKEND_UP);
        }
        stats.record_request(0);
        stats.record_request(0);
        stats.record_request(2);
        assert_eq!(pick_backend(&links, &stats), Some(1));
    }

    #[test]
    fn advertised_depth_counts_toward_load() {
        let (links, stats) = fleet(2);
        stats.set_state(0, BACKEND_UP);
        stats.set_state(1, BACKEND_UP);
        // backend 0 advertised a deep queue; 1 is idle
        links[0].depth.store(10, Ordering::Relaxed);
        assert_eq!(pick_backend(&links, &stats), Some(1));
    }

    #[test]
    fn soft_limited_backends_shed_new_work_and_the_spill_is_counted() {
        let (links, stats) = fleet(2);
        stats.set_state(0, BACKEND_UP);
        stats.set_state(1, BACKEND_UP);
        links[0].soft_limited.store(true, Ordering::Relaxed);
        // 0 is less loaded, but soft-limited: 1 gets the work
        stats.record_request(1);
        assert_eq!(pick_backend(&links, &stats), Some(1));
        assert_eq!(stats.snapshot()[0].spills, 1);
    }

    #[test]
    fn draining_is_routable_only_as_a_last_resort() {
        let (links, stats) = fleet(2);
        stats.set_state(0, BACKEND_DRAINING);
        stats.set_state(1, BACKEND_UP);
        // the draining backend is idle, the up one loaded — up still wins
        stats.record_request(1);
        assert_eq!(pick_backend(&links, &stats), Some(1));
        // with the up one gone, draining beats nothing
        stats.set_state(1, BACKEND_DOWN);
        assert_eq!(pick_backend(&links, &stats), Some(0));
    }
}
