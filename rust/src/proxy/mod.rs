//! The fault-tolerant proxy tier (`impulse proxy`).
//!
//! A front tier that speaks the framed protocol of `docs/PROTOCOL.md`
//! on both sides: clients connect to the proxy exactly as they would
//! to a single `impulse serve --listen` backend, and the proxy fans
//! their requests out over a fleet of backends — re-keying request
//! ids onto shared per-backend links the same way [`ServeCore`]
//! re-keys client sessions onto its batcher queue. Full semantics in
//! `docs/PROXY.md`; the pieces:
//!
//! - [`backend`] — the per-backend upstream link: shared write half,
//!   the in-flight [`ProxyPending`] table, and the reader thread that
//!   relays responses back to their clients.
//! - [`router`] — routing policy: least-loaded within health-tiered
//!   preference (healthy first, soft-limited next, draining last),
//!   with spill accounting when a constrained backend sheds work.
//! - [`health`] — active `StatsRequest` probes on fresh connections;
//!   one failure demotes `Up → Draining`, repeated failure declares
//!   `Down` (catches black-holed backends passive detection misses).
//! - [`listener`] — the client-facing accept loop ([`serve_proxy`]):
//!   local hello negotiation, per-frame classification into
//!   [`ReqKind`], stream-id extraction for pin routing.
//! - [`fault`] — the fault-injection relay ([`FaultRelay`]) tests and
//!   `impulse loadgen --chaos` use to kill, stall, or black-hole a
//!   backend mid-run.
//!
//! Failover contract: when a backend dies, in-flight **idempotent**
//! requests (one-shots, unacknowledged opens) are transparently
//! re-submitted to a survivor, bounded by `retry_max` attempts and
//! the per-request `request_deadline`; stream-pinned requests are
//! answered with [`ErrorCode::BackendLost`] — an honest error, never
//! a hang — because the membrane state they address died with the
//! backend. Streams (`StreamOpen`..`StreamAck`) pin to one backend
//! for their whole life; everything else balances per request.
//!
//! [`ServeCore`]: crate::serve::ServeCore
//! [`ErrorCode::BackendLost`]: crate::serve::ErrorCode::BackendLost
//! [`ProxyPending`]: backend::ProxyPending
//! [`ReqKind`]: backend::ReqKind
//! [`serve_proxy`]: listener::serve_proxy
//! [`FaultRelay`]: fault::FaultRelay

#![warn(missing_docs)]

pub mod backend;
pub mod fault;
pub mod health;
pub mod listener;
pub mod router;

pub use backend::{BackendLink, ClientHandle, ProxyPending, ReqKind};
pub use fault::{FaultMode, FaultRelay};
pub use health::{probe, HEALTH_FAILS_TO_DOWN};
pub use listener::{serve_proxy, ProxyServeHandle};
pub use router::pick_backend;

use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::obs::trace::{elapsed_us, Phase, Span, TraceRecorder};
use crate::serve::{
    error_frame, hello_caps_payload, ErrorCode, Frame, FrameReader, PayloadType,
    PROTOCOL_VERSION, SUPPORTED_CAPS,
};
use crate::telemetry::{ProxyStats, BACKEND_DOWN, BACKEND_UP};
use crate::Result;

/// How long blocking reads poll before rechecking stop conditions
/// (same cadence as the serve listener).
const POLL: Duration = Duration::from_millis(50);

/// Upper bound on one blocking socket write (see the serve listener's
/// rationale: a peer that stops reading must not wedge a thread).
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Configuration for [`ProxyCore::start`].
#[derive(Clone)]
pub struct ProxyOptions {
    /// Backend addresses (at least one).
    pub backends: Vec<String>,
    /// Interval between active health-probe rounds.
    pub health_interval: Duration,
    /// Per-probe timeout (also bounds backend connect attempts).
    pub health_timeout: Duration,
    /// Maximum transparent re-submissions per idempotent request.
    pub retry_max: u32,
    /// Hard per-request deadline; re-submission never crosses it.
    pub request_deadline: Duration,
    /// First reconnect delay after a backend death (doubles per
    /// failure up to `reconnect_max`).
    pub reconnect_base: Duration,
    /// Reconnect backoff ceiling.
    pub reconnect_max: Duration,
    /// Span recorder for `ProxyHop` spans (`--trace-dir`).
    pub trace: Option<Arc<TraceRecorder>>,
}

impl ProxyOptions {
    /// Defaults for `backends`: 500 ms health interval, 1 s probe
    /// timeout, 2 retries, 10 s request deadline, 100 ms–5 s
    /// reconnect backoff, no tracing.
    pub fn new(backends: Vec<String>) -> ProxyOptions {
        ProxyOptions {
            backends,
            health_interval: Duration::from_millis(500),
            health_timeout: Duration::from_secs(1),
            retry_max: 2,
            request_deadline: Duration::from_secs(10),
            reconnect_base: Duration::from_millis(100),
            reconnect_max: Duration::from_secs(5),
            trace: None,
        }
    }
}

/// The proxy's shared state: one [`BackendLink`] per backend, the
/// stream pin map, and the failover machinery. One `ProxyCore` serves
/// every client connection of one `impulse proxy` process.
pub struct ProxyCore {
    pub(crate) opts: ProxyOptions,
    pub(crate) links: Vec<BackendLink>,
    stats: Arc<ProxyStats>,
    /// Upstream request-id generator. Global (not per-link) so a
    /// stream id stays unique even if its open is re-submitted to a
    /// different backend — and because backend stream tables are
    /// scoped per connection, and all proxied clients share one
    /// upstream connection per backend.
    next_upstream_id: AtomicU64,
    /// Upstream stream id → backend index, for the life of the stream.
    pins: Mutex<HashMap<u64, usize>>,
    /// Streams whose backend died: subsequent operations answer
    /// `BackendLost` (not `StreamExpired` — the client should know
    /// the state is gone through no fault of its own). Entries are
    /// dropped when the owning client disconnects.
    lost_streams: Mutex<HashSet<u64>>,
    next_conn: AtomicU64,
    stop: Arc<AtomicBool>,
    trace: Option<Arc<TraceRecorder>>,
    health: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ProxyCore {
    /// Build the links (all starting `Down`), then spawn the
    /// reconnect loops (which bring backends `Up`) and the health
    /// prober. Returns immediately — callers that need a routable
    /// fleet poll [`ProxyCore::up_backends`].
    pub fn start(opts: ProxyOptions) -> Result<Arc<ProxyCore>> {
        anyhow::ensure!(!opts.backends.is_empty(), "proxy needs at least one backend");
        let stats = Arc::new(ProxyStats::new(&opts.backends));
        let links = opts.backends.iter().map(|a| BackendLink::new(a.clone())).collect();
        let trace = opts.trace.clone();
        let core = Arc::new(ProxyCore {
            opts,
            links,
            stats,
            next_upstream_id: AtomicU64::new(1),
            pins: Mutex::new(HashMap::new()),
            lost_streams: Mutex::new(HashSet::new()),
            next_conn: AtomicU64::new(1),
            stop: Arc::new(AtomicBool::new(false)),
            trace,
            health: Mutex::new(None),
        });
        for idx in 0..core.links.len() {
            spawn_reconnect(Arc::clone(&core), idx, Duration::ZERO);
        }
        let h = {
            let core = Arc::clone(&core);
            std::thread::spawn(move || health::health_loop(core))
        };
        *core.health.lock().expect("health handle poisoned") = Some(h);
        Ok(core)
    }

    /// The per-backend counters (also the routing state source).
    pub fn stats(&self) -> &ProxyStats {
        &self.stats
    }

    /// Backends currently `Up` (routable and unconstrained or not).
    pub fn up_backends(&self) -> usize {
        self.stats.up_count()
    }

    /// The backend fleet, as given in [`ProxyOptions::backends`].
    pub fn backend_addrs(&self) -> &[String] {
        &self.opts.backends
    }

    /// Whether [`ProxyCore::shutdown`] has been called.
    pub(crate) fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Take a fresh client connection id.
    pub(crate) fn next_conn_id(&self) -> u64 {
        self.next_conn.fetch_add(1, Ordering::SeqCst)
    }

    /// Stop the health prober and reconnect loops and tear down every
    /// upstream link. Reader threads notice within one poll tick.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for link in &self.links {
            if let Some(s) = link.writer.lock().expect("writer poisoned").take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        if let Some(h) = self.health.lock().expect("health handle poisoned").take() {
            let _ = h.join();
        }
    }

    /// Route one client request. Stream operations follow their pin;
    /// everything else loops over [`pick_backend`] until a forward
    /// sticks, retries are exhausted, or no backend is left — in
    /// which case the client gets an honest `BackendLost` answer.
    pub(crate) fn submit(self: &Arc<Self>, mut p: ProxyPending) {
        match p.kind {
            ReqKind::StreamOp { stream_id } => {
                let idx = self.pins.lock().expect("pins poisoned").get(&stream_id).copied();
                match idx {
                    Some(idx) => {
                        if let Err(p) = self.forward(idx, p) {
                            // the write tore the link down; this
                            // stream's state died with it
                            self.answer_backend_lost(&p, "backend died holding the stream");
                        }
                    }
                    None if self.lost_streams.lock().expect("lost set poisoned").contains(&stream_id) => {
                        self.answer_backend_lost(
                            &p,
                            &format!("stream {stream_id}'s backend died; re-open and replay"),
                        );
                    }
                    None => {
                        // never pinned here (or already closed): same
                        // answer a backend gives for an unknown stream
                        let msg = format!("stream {stream_id} is not open on this proxy");
                        self.answer_error(&p, ErrorCode::StreamExpired, &msg);
                    }
                }
            }
            ReqKind::OneShot | ReqKind::StreamOpen => loop {
                let Some(idx) = router::pick_backend(&self.links, &self.stats) else {
                    self.stats.record_no_backend();
                    self.answer_backend_lost(&p, "no healthy backend");
                    return;
                };
                match self.forward(idx, p) {
                    Ok(()) => return,
                    Err(back) => {
                        p = back;
                        p.attempts += 1;
                        if p.attempts > self.opts.retry_max || Instant::now() >= p.deadline {
                            self.answer_backend_lost(&p, "retries exhausted");
                            return;
                        }
                        self.stats.record_retry(idx);
                    }
                }
            },
        }
    }

    /// Re-key the request onto a fresh upstream id, register it in
    /// the link's pending table, and write it. On a write failure the
    /// pending entry is reclaimed (`Err`) and the link reported down;
    /// `Ok` after a failed write means a concurrent death report
    /// already drained the entry and owns its fate.
    pub(crate) fn forward(self: &Arc<Self>, idx: usize, p: ProxyPending) -> std::result::Result<(), ProxyPending> {
        let link = &self.links[idx];
        let uid = self.next_upstream_id.fetch_add(1, Ordering::SeqCst);
        if matches!(p.kind, ReqKind::StreamOpen) {
            // provisional pin: retracted if the open errors or its
            // backend dies before acknowledging
            self.pins.lock().expect("pins poisoned").insert(uid, idx);
            if let Some(c) = &p.client {
                c.streams.lock().expect("stream set poisoned").insert(uid);
            }
        }
        let frame = Frame::new(p.ty, uid, p.payload.clone()).with_flags(p.flags);
        link.pending.lock().expect("pending poisoned").insert(uid, p);
        self.stats.record_request(idx);
        let generation = link.generation.load(Ordering::SeqCst);
        let wrote = {
            let mut g = link.writer.lock().expect("writer poisoned");
            match g.as_mut() {
                Some(s) => frame.write_to(s).is_ok(),
                None => false,
            }
        };
        if wrote {
            return Ok(());
        }
        let reclaimed = link.pending.lock().expect("pending poisoned").remove(&uid);
        if let Some(p) = &reclaimed {
            self.stats.record_done(idx);
            if matches!(p.kind, ReqKind::StreamOpen) {
                self.pins.lock().expect("pins poisoned").remove(&uid);
                if let Some(c) = &p.client {
                    c.streams.lock().expect("stream set poisoned").remove(&uid);
                }
            }
        }
        self.link_down(idx, generation, "write failed");
        match reclaimed {
            Some(p) => Err(p),
            None => Ok(()), // a concurrent death report drained it first
        }
    }

    /// A response frame arrived on backend `idx`'s link: fold in its
    /// backpressure advertisement, match it to its pending request,
    /// maintain the pin map, re-key it to the client's request id and
    /// relay it.
    pub(crate) fn on_upstream_frame(&self, idx: usize, f: Frame) {
        let link = &self.links[idx];
        link.observe_flags(f.flags);
        let p = match link.pending.lock().expect("pending poisoned").remove(&f.request_id) {
            Some(p) => p,
            None => return, // stale answer from before a failover — drop
        };
        self.stats.record_done(idx);
        let is_error = f.payload_type == PayloadType::Error;
        match p.kind {
            ReqKind::StreamOpen => {
                if is_error {
                    // the open failed (e.g. stream cap): retract the pin
                    self.pins.lock().expect("pins poisoned").remove(&f.request_id);
                    if let Some(c) = &p.client {
                        c.streams.lock().expect("stream set poisoned").remove(&f.request_id);
                    }
                }
            }
            ReqKind::StreamOp { stream_id } => {
                let gone = if p.ty == PayloadType::StreamClose {
                    // closed (or errored while closing): the pin is done
                    true
                } else if is_error {
                    // only errors that actually evict backend state end
                    // the pin — a Malformed append leaves the lane alive
                    matches!(
                        crate::serve::decode_error(&f.payload),
                        Ok((code, _))
                            if code == ErrorCode::StreamExpired.as_u16()
                                || code == ErrorCode::InferenceFailed.as_u16()
                    )
                } else {
                    false
                };
                if gone {
                    self.pins.lock().expect("pins poisoned").remove(&stream_id);
                    self.lost_streams.lock().expect("lost set poisoned").remove(&stream_id);
                    if let Some(c) = &p.client {
                        c.streams.lock().expect("stream set poisoned").remove(&stream_id);
                    }
                }
            }
            ReqKind::OneShot => {}
        }
        if let Some(c) = &p.client {
            let mut out = f;
            out.request_id = p.external_id;
            // flags (backpressure advertisement, trace-echo bit) are
            // relayed verbatim — the backend's word is the truth the
            // client negotiated for
            let _ = c.write(&out);
        }
        self.record_hop(&p, !is_error);
    }

    /// Backend `idx`'s link (of generation `generation`) died. Tear
    /// the socket down, fail over its streams, drain its in-flight
    /// table — re-submitting idempotent work, answering the rest with
    /// `BackendLost` — and start the reconnect loop. Idempotent: the
    /// generation check and the `Down` swap make concurrent reports
    /// (reader error, failed write, health prober) collapse to one.
    pub(crate) fn link_down(self: &Arc<Self>, idx: usize, generation: u64, cause: &str) {
        if self.stopped() {
            return;
        }
        let link = &self.links[idx];
        if link.generation.load(Ordering::SeqCst) != generation {
            return; // a newer link already replaced the one that died
        }
        let prior = self.stats.set_state(idx, BACKEND_DOWN);
        if prior == BACKEND_DOWN {
            return; // another report got here first
        }
        self.stats.record_failover(idx);
        crate::warn!("proxy", "backend {} down ({cause}); failing over", link.addr);
        if let Some(s) = link.writer.lock().expect("writer poisoned").take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        link.soft_limited.store(false, Ordering::Relaxed);
        link.depth.store(0, Ordering::Relaxed);
        link.health_fails.store(0, Ordering::SeqCst);
        // streams pinned here lost their membrane state with the backend
        let mut lost: HashSet<u64> = {
            let mut pins = self.pins.lock().expect("pins poisoned");
            let ids: Vec<u64> =
                pins.iter().filter(|&(_, &i)| i == idx).map(|(&s, _)| s).collect();
            for s in &ids {
                pins.remove(s);
            }
            ids.into_iter().collect()
        };
        // drain in-flight work: re-submit what is provably safe
        // (stateless one-shots; opens never acknowledged), answer the
        // rest honestly
        let drained: Vec<(u64, ProxyPending)> = {
            let mut pend = link.pending.lock().expect("pending poisoned");
            pend.drain().collect()
        };
        let now = Instant::now();
        for (uid, mut p) in drained {
            self.stats.record_done(idx);
            let retryable = matches!(p.kind, ReqKind::OneShot | ReqKind::StreamOpen);
            if retryable && p.attempts < self.opts.retry_max && now < p.deadline {
                if matches!(p.kind, ReqKind::StreamOpen) {
                    // the open never surfaced to the client: retract its
                    // provisional bookkeeping and let it pin fresh
                    lost.remove(&uid);
                    if let Some(c) = &p.client {
                        c.streams.lock().expect("stream set poisoned").remove(&uid);
                    }
                }
                p.attempts += 1;
                self.stats.record_retry(idx);
                self.submit(p);
            } else {
                self.answer_backend_lost(&p, cause);
            }
        }
        if !lost.is_empty() {
            crate::warn!(
                "proxy",
                "backend {}: {} pinned stream(s) lost their membrane state",
                link.addr,
                lost.len()
            );
            let mut set = self.lost_streams.lock().expect("lost set poisoned");
            for s in lost {
                set.insert(s);
                self.stats.record_stream_lost(idx);
            }
        }
        spawn_reconnect(Arc::clone(self), idx, self.opts.reconnect_base);
    }

    /// A client connection vanished: close its still-pinned streams
    /// on their backends (fire-and-forget janitorial frames — the
    /// backend TTL sweep is the backstop) and drop its lost-stream
    /// tombstones.
    pub(crate) fn close_client_streams(self: &Arc<Self>, ids: Vec<u64>) {
        let now = Instant::now();
        for sid in ids {
            self.lost_streams.lock().expect("lost set poisoned").remove(&sid);
            let idx = self.pins.lock().expect("pins poisoned").get(&sid).copied();
            let Some(idx) = idx else { continue };
            let p = ProxyPending {
                ty: PayloadType::StreamClose,
                flags: 0,
                payload: crate::serve::encode_stream_ref(sid),
                external_id: 0,
                client: None,
                attempts: self.opts.retry_max, // never re-submitted
                deadline: now,
                enqueued: now,
                kind: ReqKind::StreamOp { stream_id: sid },
            };
            let _ = self.forward(idx, p);
        }
    }

    /// Answer a request with an `Error` frame (when it has a client
    /// to answer) and close out its proxy-hop span.
    fn answer_error(&self, p: &ProxyPending, code: ErrorCode, msg: &str) {
        if let Some(c) = &p.client {
            let _ = c.write(&error_frame(p.external_id, code, msg));
        }
        self.record_hop(p, false);
    }

    /// The honest failover answer: the backend this request (or its
    /// stream) was routed to is gone and transparent recovery was not
    /// possible.
    fn answer_backend_lost(&self, p: &ProxyPending, why: &str) {
        self.answer_error(p, ErrorCode::BackendLost, &format!("backend lost: {why}"));
    }

    /// Record this request's dwell inside the proxy as a `ProxyHop`
    /// span (request accepted → response relayed / error answered).
    fn record_hop(&self, p: &ProxyPending, ok: bool) {
        if let Some(tr) = self.trace.as_deref() {
            let conn = p.client.as_ref().map(|c| c.conn_id).unwrap_or(0);
            tr.record(
                Span::new(
                    Phase::ProxyHop,
                    tr.next_trace_id(),
                    p.external_id,
                    conn,
                    tr.us_of(p.enqueued),
                    elapsed_us(p.enqueued),
                )
                .with_ok(ok),
            );
        }
    }

    /// Dial backend `idx`, run the extended hello (both capability
    /// bits, so backpressure advertisements and trace-echo trailers
    /// flow through the link), install the writer, and spawn the
    /// link's reader thread under a fresh generation.
    fn connect_link(self: &Arc<Self>, idx: usize) -> Result<()> {
        let link = &self.links[idx];
        let sa = resolve(&link.addr)?;
        let stream = TcpStream::connect_timeout(&sa, self.opts.health_timeout)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(self.opts.health_timeout))?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        let mut w = stream.try_clone()?;
        Frame::new(
            PayloadType::Hello,
            0,
            hello_caps_payload(PROTOCOL_VERSION, PROTOCOL_VERSION, SUPPORTED_CAPS),
        )
        .write_to(&mut w)?;
        let mut reader = FrameReader::new(stream.try_clone()?);
        match reader.next_frame() {
            Ok(Some(f)) if f.payload_type == PayloadType::HelloAck => {}
            Ok(Some(f)) => {
                anyhow::bail!("backend {} answered hello with {:?}", link.addr, f.payload_type)
            }
            Ok(None) => anyhow::bail!("backend {} closed during hello", link.addr),
            Err(e) => anyhow::bail!("backend {} hello failed: {e}", link.addr),
        }
        // socket options are shared across the clones: from here the
        // reader polls at the listener cadence
        stream.set_read_timeout(Some(POLL))?;
        let generation = link.generation.fetch_add(1, Ordering::SeqCst) + 1;
        *link.writer.lock().expect("writer poisoned") = Some(w);
        link.soft_limited.store(false, Ordering::Relaxed);
        link.depth.store(0, Ordering::Relaxed);
        link.health_fails.store(0, Ordering::SeqCst);
        self.stats.set_state(idx, BACKEND_UP);
        crate::info!("proxy", "backend {} up (generation {generation})", link.addr);
        let core = Arc::clone(self);
        std::thread::spawn(move || backend::link_reader(core, idx, generation, reader));
        Ok(())
    }
}

/// Spawn the reconnect loop for backend `idx`: try after
/// `initial_delay`, then back off exponentially (base → ×2 → capped)
/// until the link connects or the proxy stops.
fn spawn_reconnect(core: Arc<ProxyCore>, idx: usize, initial_delay: Duration) {
    std::thread::spawn(move || {
        let mut delay = initial_delay;
        loop {
            if delay > Duration::ZERO && !sleep_while_running(&core, delay) {
                return;
            }
            if core.stopped() {
                return;
            }
            match core.connect_link(idx) {
                Ok(()) => return,
                Err(e) => {
                    crate::debug!(
                        "proxy",
                        "backend {} connect failed: {e:#}",
                        core.links[idx].addr
                    );
                    delay = if delay.is_zero() {
                        core.opts.reconnect_base
                    } else {
                        (delay * 2).min(core.opts.reconnect_max)
                    };
                }
            }
        }
    });
}

/// Sleep `total` in short slices, waking early on shutdown. Returns
/// `false` when the proxy stopped mid-sleep.
pub(crate) fn sleep_while_running(core: &ProxyCore, total: Duration) -> bool {
    let mut left = total;
    while left > Duration::ZERO {
        if core.stopped() {
            return false;
        }
        let slice = left.min(Duration::from_millis(25));
        std::thread::sleep(slice);
        left = left.saturating_sub(slice);
    }
    !core.stopped()
}

/// Resolve an address string to its first socket address.
pub(crate) fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("cannot resolve '{addr}'"))
}
