//! Observability: per-request lifecycle tracing and structured
//! logging for the serving stack.
//!
//! Three zero-dependency pieces:
//!
//! - [`trace`] — the span recorder ([`trace::TraceRecorder`]), the
//!   Chrome trace-event JSON export behind `impulse serve
//!   --trace-dir`, and the reader used by `impulse trace`.
//! - [`log`] — the leveled stderr logger behind the crate-level
//!   [`crate::error!`] / [`crate::warn!`] / [`crate::info!`] /
//!   [`crate::debug!`] macros.
//! - [`json`] — the minimal JSON parser/escaper the trace reader is
//!   built on (the crate has no serde).
//!
//! The span model, trace-event schema, wire negotiation and log line
//! format are documented in `docs/OBSERVABILITY.md`.

pub mod json;
pub mod log;
pub mod trace;
