//! Leveled structured logging to stderr.
//!
//! A zero-dependency logger with a stable, machine-greppable line
//! format:
//!
//! ```text
//! 1723111845.123 INFO target=serve listening addr=127.0.0.1:7979
//! ```
//!
//! i.e. `ts level target msg key=val`: a Unix timestamp with
//! millisecond precision, the level token, a `target=` component
//! naming the subsystem, then the message — with any structured
//! `key=value` pairs appended by the caller inside the message text.
//!
//! Call sites use the crate-level [`crate::error!`], [`crate::warn!`],
//! [`crate::info!`] and [`crate::debug!`] macros, which check the
//! global level filter *before* formatting (a disabled level costs one
//! relaxed atomic load). The filter defaults to [`Level::Info`] and is
//! set either by the CLI's `--log-level` flag or the `IMPULSE_LOG`
//! environment variable (flag wins) via [`init`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or dropped work — always worth a line.
    Error = 0,
    /// Degraded but serving (e.g. a rejected connection).
    Warn = 1,
    /// Lifecycle events: startup banners, shutdown, drains.
    Info = 2,
    /// Per-request / per-frame detail; off by default.
    Debug = 3,
}

impl Level {
    /// The fixed token this level prints as (`ERROR`/`WARN`/…).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// Parse a level name (case-insensitive: `error`, `warn`, `info`,
/// `debug`). Returns `None` for anything else.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        _ => None,
    }
}

/// The global filter; levels numerically above it are suppressed.
static FILTER: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level filter.
pub fn set_level(l: Level) {
    FILTER.store(l as u8, Ordering::Relaxed);
}

/// The current global level filter.
pub fn level() -> Level {
    match FILTER.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// Whether a line at `l` would currently be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= FILTER.load(Ordering::Relaxed)
}

/// Initialize the filter from an explicit `--log-level` value (wins)
/// or the `IMPULSE_LOG` environment variable; an unrecognized name is
/// reported on stderr and the default ([`Level::Info`]) kept.
pub fn init(flag: Option<&str>) {
    let env = std::env::var("IMPULSE_LOG").ok();
    let chosen = flag.or(env.as_deref());
    if let Some(name) = chosen {
        match parse_level(name) {
            Some(l) => set_level(l),
            None => emit(
                Level::Warn,
                "log",
                &format!("unrecognized log level {name:?}, keeping info"),
            ),
        }
    }
}

/// Render one log line (without emitting it) — the stable
/// `ts level target msg` format the macros produce.
pub fn render(l: Level, target: &str, msg: &str) -> String {
    let d = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    format!("{}.{:03} {} target={target} {msg}", d.as_secs(), d.subsec_millis(), l.as_str())
}

/// Emit one line to stderr, bypassing the level filter (the macros
/// check [`enabled`] first so disabled levels never format).
pub fn emit(l: Level, target: &str, msg: &str) {
    eprintln!("{}", render(l, target, msg));
}

/// Log at an explicit [`Level`]: `log_event!(level, target, fmt...)`.
/// Prefer the leveled shorthands [`crate::error!`] / [`crate::warn!`]
/// / [`crate::info!`] / [`crate::debug!`].
#[macro_export]
macro_rules! log_event {
    ($lvl:expr, $target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($lvl) {
            $crate::obs::log::emit($lvl, $target, &format!($($arg)+));
        }
    };
}

/// Log an error-level line: `error!("serve", "accept failed err={e}")`.
#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)+) => {
        $crate::log_event!($crate::obs::log::Level::Error, $target, $($arg)+)
    };
}

/// Log a warn-level line: `warn!("serve", "draining on signal")`.
#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)+) => {
        $crate::log_event!($crate::obs::log::Level::Warn, $target, $($arg)+)
    };
}

/// Log an info-level line: `info!("serve", "listening addr={addr}")`.
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)+) => {
        $crate::log_event!($crate::obs::log::Level::Info, $target, $($arg)+)
    };
}

/// Log a debug-level line (suppressed at the default filter).
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)+) => {
        $crate::log_event!($crate::obs::log::Level::Debug, $target, $($arg)+)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(parse_level("ERROR"), Some(Level::Error));
        assert_eq!(parse_level("Warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("verbose"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn filter_gates_levels() {
        // note: the filter is process-global; restore it afterwards so
        // parallel tests observing the default are unaffected
        let before = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(before);
    }

    #[test]
    fn line_format_is_stable() {
        let line = render(Level::Info, "serve", "listening addr=1.2.3.4:5");
        let mut parts = line.splitn(4, ' ');
        let ts = parts.next().unwrap();
        assert!(ts.contains('.'), "timestamp must be secs.millis: {ts}");
        assert!(ts.replace('.', "").chars().all(|c| c.is_ascii_digit()));
        assert_eq!(parts.next(), Some("INFO"));
        assert_eq!(parts.next(), Some("target=serve"));
        assert_eq!(parts.next(), Some("listening addr=1.2.3.4:5"));
    }
}
