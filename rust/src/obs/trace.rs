//! Per-request lifecycle tracing: the span recorder and Chrome-trace
//! export.
//!
//! A [`TraceRecorder`] captures one span tree per served request
//! across the five serving chokepoints — frame **decode** in the TCP
//! listener, **queue** wait and **batch** formation in the
//! coordinator, engine **execute** in `serve_batch` (with the
//! instruction-histogram cycle/energy delta attached as attributes),
//! and the response **write** inside the connection writer lock — plus
//! stream-table appends and loadgen's client-observed operations.
//! Spans carry the wire `request_id` and a process-unique trace id, so
//! the phases of one request correlate across threads.
//!
//! Recording mirrors the sharded-histogram trick
//! (`telemetry/histogram.rs`): spans are striped across
//! cache-line-aligned shards with a stable per-thread shard index, so
//! the worker, reader and responder threads never contend on one
//! buffer (each push is an uncontended short critical section on the
//! caller's own stripe). When nothing drains the recorder, each shard
//! caps its buffer and counts drops instead of growing without bound.
//!
//! Export is the Chrome trace-event JSON format — complete (`"ph":
//! "X"`) events with microsecond `ts`/`dur` — loadable in
//! `chrome://tracing` and Perfetto, summarized offline by
//! `impulse trace`. See `docs/OBSERVABILITY.md`.

use crate::obs::json::JsonValue;
use crate::Result;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Stripe count (matches the telemetry histogram: comfortably covers
/// the worker + reader + responder thread population).
const N_SHARDS: usize = 8;

/// Per-shard buffered-span cap: past it, new spans are dropped and
/// counted. 64Ki spans ≈ 6 MiB per shard worst case — a bound, not a
/// budget; the flusher drains every rotation interval.
const SHARD_CAP: usize = 64 * 1024;

/// How often the [`TraceFlusher`] drains the recorder into a new
/// rotation file.
const FLUSH_INTERVAL: Duration = Duration::from_millis(500);

/// A lifecycle phase — the `name` of the exported trace event.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Frame + payload decode in the TCP listener's reader thread.
    Decode,
    /// Submit until the batcher picked the request into a batch.
    Queue,
    /// Batch formation until a worker began executing the batch.
    Batch,
    /// Engine execution of the (possibly fused) batch.
    Execute,
    /// Response encode + socket write inside the writer lock.
    Write,
    /// One stream-table append (pinned-lane integration).
    StreamAppend,
    /// A client-observed operation (loadgen's `--trace-dir`).
    Client,
    /// One request's dwell inside a proxy tier: forwarded upstream
    /// until the backend's response was relayed back to the client
    /// (`impulse proxy --trace-dir`).
    ProxyHop,
}

impl Phase {
    /// The five phases every one-shot request passes through, in
    /// lifecycle order.
    pub const LIFECYCLE: [Phase; 5] =
        [Phase::Decode, Phase::Queue, Phase::Batch, Phase::Execute, Phase::Write];

    /// The stable event name this phase exports as.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Decode => "decode",
            Phase::Queue => "queue",
            Phase::Batch => "batch",
            Phase::Execute => "execute",
            Phase::Write => "write",
            Phase::StreamAppend => "stream_append",
            Phase::Client => "client",
            Phase::ProxyHop => "proxy_hop",
        }
    }

    /// Parse an exported event name back into a phase.
    pub fn from_name(s: &str) -> Option<Phase> {
        match s {
            "decode" => Some(Phase::Decode),
            "queue" => Some(Phase::Queue),
            "batch" => Some(Phase::Batch),
            "execute" => Some(Phase::Execute),
            "write" => Some(Phase::Write),
            "stream_append" => Some(Phase::StreamAppend),
            "client" => Some(Phase::Client),
            "proxy_hop" => Some(Phase::ProxyHop),
            _ => None,
        }
    }
}

/// One recorded span: a phase of one request's lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    /// The process-unique trace id correlating this request's phases.
    pub trace_id: u64,
    /// The wire request id (client-chosen; unique per connection only).
    pub request_id: u64,
    /// The serving connection id (loadgen: the connection index).
    pub conn: u64,
    /// Which lifecycle phase this span covers.
    pub phase: Phase,
    /// Start, in microseconds since the recorder's epoch.
    pub start_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Worker that executed the batch (execute spans; 0 otherwise).
    pub worker: u32,
    /// Fused batch width (execute spans; 0 otherwise).
    pub batch: u32,
    /// Attributed macro cycles (execute/stream-append spans).
    pub cycles: u64,
    /// Attributed energy in femtojoules (execute spans).
    pub energy_fj: u64,
    /// Whether the phase completed successfully.
    pub ok: bool,
}

impl Span {
    /// A span with the cost/worker attributes zeroed and `ok` set.
    pub fn new(
        phase: Phase,
        trace_id: u64,
        request_id: u64,
        conn: u64,
        start_us: u64,
        dur_us: u64,
    ) -> Span {
        Span {
            trace_id,
            request_id,
            conn,
            phase,
            start_us,
            dur_us,
            worker: 0,
            batch: 0,
            cycles: 0,
            energy_fj: 0,
            ok: true,
        }
    }

    /// Attach the executing worker and fused batch width.
    pub fn with_worker(mut self, worker: u32, batch: u32) -> Span {
        self.worker = worker;
        self.batch = batch;
        self
    }

    /// Attach the attributed cycle and energy cost.
    pub fn with_cost(mut self, cycles: u64, energy_fj: u64) -> Span {
        self.cycles = cycles;
        self.energy_fj = energy_fj;
        self
    }

    /// Set the success flag.
    pub fn with_ok(mut self, ok: bool) -> Span {
        self.ok = ok;
        self
    }
}

/// Trace context attached to a request as it crosses the listener →
/// coordinator seam, so the router-side spans correlate with the
/// listener-side ones.
#[derive(Clone, Copy, Debug)]
pub struct TraceCtx {
    /// The process-unique trace id minted at decode time.
    pub trace_id: u64,
    /// The serving connection id.
    pub conn: u64,
    /// The wire request id (the client's correlation key).
    pub request_id: u64,
    /// Duration of the decode phase, µs (carried for the wire echo).
    pub decode_us: u64,
    /// Whether the client requested the timing-breakdown echo
    /// (`FLAG_TRACE_ECHO` on the request of a `CAP_TRACE_ECHO`
    /// negotiated connection).
    pub echo: bool,
}

/// Phase timings carried back on a [`crate::coordinator::Response`] so
/// the responder can record the write span under the right trace id
/// and answer trace-echo requests.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceSummary {
    /// The trace id minted at decode time.
    pub trace_id: u64,
    /// Decode-phase duration, µs.
    pub decode_us: u64,
    /// Queue-phase duration, µs.
    pub queue_us: u64,
    /// Batch-formation duration, µs.
    pub batch_us: u64,
    /// Execute-phase duration, µs.
    pub execute_us: u64,
    /// Whether the response should carry the wire timing echo.
    pub echo: bool,
}

/// One cache-line-aligned stripe of the span buffer.
#[repr(align(128))]
struct Shard {
    spans: Mutex<Vec<Span>>,
}

/// The stable per-thread shard index (round-robin on first use — no
/// hashing on the hot path; same idiom as the telemetry histogram).
fn shard_index() -> usize {
    use std::cell::Cell;
    thread_local! {
        static IDX: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    IDX.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            static NEXT: AtomicUsize = AtomicUsize::new(0);
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v % N_SHARDS
    })
}

/// The span recorder: sharded per-thread buffers, a monotonic trace-id
/// counter, and a single time epoch all spans are measured against.
///
/// Threaded through `ServerOptions` as an `Option<Arc<TraceRecorder>>`
/// exactly like the telemetry registry: `None` (the default) costs one
/// `Option` branch per chokepoint and records nothing.
pub struct TraceRecorder {
    epoch: Instant,
    next_trace: AtomicU64,
    dropped: AtomicU64,
    shards: Vec<Shard>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    /// A fresh recorder; its construction instant is the `ts` epoch
    /// for every span it records.
    pub fn new() -> TraceRecorder {
        TraceRecorder {
            epoch: Instant::now(),
            next_trace: AtomicU64::new(1),
            dropped: AtomicU64::new(0),
            shards: (0..N_SHARDS).map(|_| Shard { spans: Mutex::new(Vec::new()) }).collect(),
        }
    }

    /// Mint a process-unique trace id.
    pub fn next_trace_id(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Microseconds between the recorder epoch and `t` (0 if `t`
    /// precedes the epoch).
    pub fn us_of(&self, t: Instant) -> u64 {
        saturating_us(t.saturating_duration_since(self.epoch))
    }

    /// Record one span into the caller's shard.
    pub fn record(&self, span: Span) {
        let mut g = self.shards[shard_index()].spans.lock().expect("trace shard poisoned");
        if g.len() >= SHARD_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        g.push(span);
    }

    /// Take every buffered span (ordered by start time across shards).
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.append(&mut s.spans.lock().expect("trace shard poisoned"));
        }
        out.sort_by_key(|s| (s.start_us, s.trace_id));
        out
    }

    /// Spans currently buffered across all shards.
    pub fn pending(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.spans.lock().expect("trace shard poisoned").len())
            .sum()
    }

    /// Spans dropped at the shard cap since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// A duration as saturating microseconds.
pub fn saturating_us(d: Duration) -> u64 {
    u64::try_from(d.as_micros()).unwrap_or(u64::MAX)
}

/// Microseconds elapsed since `t0`.
pub fn elapsed_us(t0: Instant) -> u64 {
    saturating_us(t0.elapsed())
}

// ---------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------

/// Serialize spans as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`): complete events (`"ph": "X"`) with
/// microsecond `ts`/`dur`, `pid` = the server process id, `tid` = the
/// serving connection id, and the request attribution under `args`.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let pid = std::process::id();
    let mut out = String::with_capacity(64 + spans.len() * 160);
    out.push_str("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n{{\"name\":\"{}\",\"cat\":\"impulse\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":{},\"tid\":{},\"args\":{{\"trace\":{},\"req\":{},\"conn\":{},\
             \"worker\":{},\"batch\":{},\"cycles\":{},\"energy_fj\":{},\"ok\":{}}}}}",
            s.phase.name(),
            s.start_us,
            s.dur_us,
            pid,
            s.conn,
            s.trace_id,
            s.request_id,
            s.conn,
            s.worker,
            s.batch,
            s.cycles,
            s.energy_fj,
            s.ok,
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// One event read back from an exported trace file.
#[derive(Clone, Debug, Default)]
pub struct TraceEvent {
    /// The phase name (`decode`, `queue`, …).
    pub name: String,
    /// The event type — always `"X"` (complete) from our writer.
    pub ph: String,
    /// Start, µs since the recorder epoch.
    pub ts: u64,
    /// Duration, µs.
    pub dur: u64,
    /// Writing process id.
    pub pid: u64,
    /// Thread/track id (the serving connection id).
    pub tid: u64,
    /// The process-unique trace id (`args.trace`).
    pub trace_id: u64,
    /// The wire request id (`args.req`).
    pub request_id: u64,
    /// The serving connection id (`args.conn`).
    pub conn: u64,
    /// Executing worker (`args.worker`).
    pub worker: u64,
    /// Fused batch width (`args.batch`).
    pub batch: u64,
    /// Attributed cycles (`args.cycles`).
    pub cycles: u64,
    /// Attributed energy in femtojoules (`args.energy_fj`).
    pub energy_fj: u64,
    /// Success flag (`args.ok`).
    pub ok: bool,
}

/// Parse a Chrome trace-event document (either the `{"traceEvents":
/// [...]}` object form our writer emits or a bare event array).
pub fn parse_chrome_trace(text: &str) -> Result<Vec<TraceEvent>> {
    let doc = JsonValue::parse(text)?;
    let events = match &doc {
        JsonValue::Arr(_) => &doc,
        _ => doc
            .get("traceEvents")
            .ok_or_else(|| anyhow::anyhow!("not a Chrome trace: no traceEvents array"))?,
    };
    let items = events
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("traceEvents is not an array"))?;
    let mut out = Vec::with_capacity(items.len());
    for (i, e) in items.iter().enumerate() {
        let field = |k: &str| e.get(k).and_then(JsonValue::as_u64).unwrap_or(0);
        let arg = |k: &str| e.get("args").and_then(|a| a.get(k)).and_then(JsonValue::as_u64);
        anyhow::ensure!(
            e.get("name").and_then(JsonValue::as_str).is_some(),
            "event {i} has no name"
        );
        out.push(TraceEvent {
            name: e.get("name").and_then(JsonValue::as_str).unwrap_or("").to_string(),
            ph: e.get("ph").and_then(JsonValue::as_str).unwrap_or("").to_string(),
            ts: field("ts"),
            dur: field("dur"),
            pid: field("pid"),
            tid: field("tid"),
            trace_id: arg("trace").unwrap_or(0),
            request_id: arg("req").unwrap_or(0),
            conn: arg("conn").unwrap_or(0),
            worker: arg("worker").unwrap_or(0),
            batch: arg("batch").unwrap_or(0),
            cycles: arg("cycles").unwrap_or(0),
            energy_fj: arg("energy_fj").unwrap_or(0),
            ok: e
                .get("args")
                .and_then(|a| a.get("ok"))
                .and_then(JsonValue::as_bool)
                .unwrap_or(true),
        });
    }
    Ok(out)
}

/// Write one rotation file (`trace-NNNNNN.json`) into `dir` and return
/// its path. Each rotation is a complete, independently loadable
/// Chrome trace document.
pub fn write_rotation(dir: &Path, seq: u64, spans: &[Span]) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("trace-{seq:06}.json"));
    std::fs::write(&path, chrome_trace_json(spans))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(path)
}

/// Load every `trace-*.json` rotation in `dir` (sorted by name, i.e.
/// by rotation sequence) into one event list.
pub fn load_trace_dir(dir: &Path) -> Result<Vec<TraceEvent>> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading trace dir {}: {e}", dir.display()))?
        .filter_map(|r| r.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("trace-") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let text = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", f.display()))?;
        out.extend(
            parse_chrome_trace(&text)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", f.display()))?,
        );
    }
    Ok(out)
}

/// The background flusher behind `impulse serve --trace-dir`: drains
/// the recorder every [`FLUSH_INTERVAL`] and writes each non-empty
/// drain as its own rotation file; [`TraceFlusher::stop`] performs the
/// final drain so shutdown loses nothing.
pub struct TraceFlusher {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl TraceFlusher {
    /// Spawn the flusher over `recorder`, rotating into `dir`.
    pub fn start(recorder: Arc<TraceRecorder>, dir: PathBuf) -> TraceFlusher {
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut seq = 0u64;
                loop {
                    let stopping = stop.load(Ordering::SeqCst);
                    let spans = recorder.drain();
                    if !spans.is_empty() {
                        match write_rotation(&dir, seq, &spans) {
                            Ok(_) => seq += 1,
                            Err(e) => {
                                crate::error!("trace", "rotation write failed err={e:#}");
                            }
                        }
                    }
                    if stopping {
                        break;
                    }
                    std::thread::sleep(FLUSH_INTERVAL);
                }
                let dropped = recorder.dropped();
                if dropped > 0 {
                    crate::warn!("trace", "spans dropped at shard cap dropped={dropped}");
                }
            })
        };
        TraceFlusher { stop, thread: Some(thread) }
    }

    /// Signal the flusher, wait for its final drain, and join it.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(phase: Phase, trace_id: u64, start: u64, dur: u64) -> Span {
        Span::new(phase, trace_id, trace_id + 100, 1, start, dur)
    }

    #[test]
    fn recorder_drains_what_it_records() {
        let tr = TraceRecorder::new();
        assert_eq!(tr.pending(), 0);
        tr.record(span(Phase::Decode, 1, 10, 5));
        tr.record(span(Phase::Execute, 1, 20, 7).with_cost(123, 456).with_worker(2, 4));
        assert_eq!(tr.pending(), 2);
        let spans = tr.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(tr.pending(), 0, "drain must empty the buffers");
        assert_eq!(spans[0].phase, Phase::Decode);
        assert_eq!(spans[1].cycles, 123);
        assert_eq!(spans[1].energy_fj, 456);
        assert_eq!(spans[1].worker, 2);
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn trace_ids_are_unique_across_threads() {
        let tr = Arc::new(TraceRecorder::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let tr = Arc::clone(&tr);
                std::thread::spawn(move || {
                    (0..100).map(|_| tr.next_trace_id()).collect::<Vec<_>>()
                })
            })
            .collect();
        let mut seen = std::collections::HashSet::new();
        for h in handles {
            for id in h.join().unwrap() {
                assert!(seen.insert(id), "trace id {id} minted twice");
            }
        }
    }

    #[test]
    fn chrome_export_roundtrips_through_the_parser() {
        let spans = vec![
            span(Phase::Decode, 7, 100, 12),
            span(Phase::Execute, 7, 130, 40).with_cost(999, 1234).with_worker(1, 2),
            span(Phase::Write, 7, 171, 3).with_ok(false),
        ];
        let doc = chrome_trace_json(&spans);
        let events = parse_chrome_trace(&doc).unwrap();
        assert_eq!(events.len(), 3);
        for (e, s) in events.iter().zip(&spans) {
            assert_eq!(e.ph, "X");
            assert_eq!(e.name, s.phase.name());
            assert_eq!(e.ts, s.start_us);
            assert_eq!(e.dur, s.dur_us);
            assert_eq!(e.pid, u64::from(std::process::id()));
            assert_eq!(e.trace_id, 7);
            assert_eq!(e.cycles, s.cycles);
            assert_eq!(e.energy_fj, s.energy_fj);
            assert_eq!(e.ok, s.ok);
        }
        // a bare array (foreign tooling) parses too
        let bare = parse_chrome_trace("[{\"name\":\"x\",\"ph\":\"X\",\"ts\":1}]").unwrap();
        assert_eq!(bare.len(), 1);
    }

    #[test]
    fn rotations_write_and_load_in_sequence() {
        let dir = std::env::temp_dir().join(format!("impulse-trace-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_rotation(&dir, 0, &[span(Phase::Decode, 1, 5, 2)]).unwrap();
        write_rotation(&dir, 1, &[span(Phase::Write, 1, 9, 1)]).unwrap();
        let events = load_trace_dir(&dir).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].name, "decode");
        assert_eq!(events[1].name, "write");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flusher_rotates_and_final_drains() {
        let dir = std::env::temp_dir().join(format!("impulse-flush-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tr = Arc::new(TraceRecorder::new());
        let flusher = TraceFlusher::start(Arc::clone(&tr), dir.clone());
        tr.record(span(Phase::Decode, 1, 1, 1));
        flusher.stop();
        let events = load_trace_dir(&dir).unwrap();
        assert_eq!(events.len(), 1, "stop must flush buffered spans");
        assert_eq!(tr.pending(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_cap_drops_instead_of_growing() {
        let tr = TraceRecorder::new();
        // all from one thread → one shard; fill it past the cap
        for i in 0..(SHARD_CAP + 10) {
            tr.record(span(Phase::Client, i as u64, i as u64, 1));
        }
        assert_eq!(tr.pending(), SHARD_CAP);
        assert_eq!(tr.dropped(), 10);
    }

    #[test]
    fn phase_names_roundtrip() {
        for p in [
            Phase::Decode,
            Phase::Queue,
            Phase::Batch,
            Phase::Execute,
            Phase::Write,
            Phase::StreamAppend,
            Phase::Client,
            Phase::ProxyHop,
        ] {
            assert_eq!(Phase::from_name(p.name()), Some(p));
        }
        assert_eq!(Phase::from_name("nope"), None);
    }
}
