//! A minimal JSON reader/escaper for the tracing subsystem.
//!
//! The crate writes Chrome trace-event files (`obs::trace`) and reads
//! them back (`impulse trace`) without external dependencies, so this
//! module carries a small recursive-descent parser over the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null). It is not a streaming parser — trace rotations are bounded
//! (a few thousand events), so whole-file parsing is fine.

use crate::Result;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as `f64`; trace fields stay well under
    /// the 2^53 integer-exact range).
    Num(f64),
    /// A string, unescaped.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, in source order (duplicate keys keep the last).
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Look up a key on an object (`None` on other variants).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(fields) => {
                fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// This value as a number, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// This value as a non-negative integer (rounded), if numeric.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| if n <= 0.0 { 0 } else { n.round() as u64 })
    }

    /// This value as a string slice, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// This value as a bool, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (quotes not
/// included).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<JsonValue> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(fields));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // surrogate pairs are not produced by our
                            // writer; map lone surrogates to U+FFFD
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is &str, so
                    // boundaries are valid)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(JsonValue::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_trace_shaped_documents() {
        let doc = r#"{"traceEvents":[{"name":"decode","ph":"X","ts":12,"dur":3,
            "pid":77,"tid":1,"args":{"trace":9,"ok":true}}]}"#;
        let v = JsonValue::parse(doc).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("decode"));
        assert_eq!(e.get("ts").unwrap().as_u64(), Some(12));
        assert_eq!(e.get("args").unwrap().get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_scalars_arrays_and_escapes() {
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse(" -2.5e2 ").unwrap(), JsonValue::Num(-250.0));
        assert_eq!(
            JsonValue::parse(r#""a\"b\n\u0041""#).unwrap(),
            JsonValue::Str("a\"b\nA".into())
        );
        assert_eq!(
            JsonValue::parse("[1, 2, 3]").unwrap().as_arr().unwrap().len(),
            3
        );
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(JsonValue::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn escape_roundtrips_through_parse() {
        let s = "line1\nline\"2\"\\end\tok";
        let doc = format!("\"{}\"", escape(s));
        assert_eq!(JsonValue::parse(&doc).unwrap(), JsonValue::Str(s.into()));
    }
}
