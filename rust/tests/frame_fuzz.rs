//! Seed-driven mutation fuzzing of the IMP1 frame layer, against both
//! the pure codec and a live TCP listener. Every case derives from a
//! pinned seed (printed on failure, so any crash is reproducible):
//! valid frames are mutated by truncation, bit flips, corrupted magic,
//! unknown type bytes, bad version bytes, oversized length prefixes,
//! and flipped CRC trailers. The codec must classify every mutant
//! without panicking; the server must answer an error frame or drop
//! the connection cleanly — never hang, never panic, and never leak a
//! pinned stream lane.

use impulse::bits::XorShiftRng;
use impulse::coordinator::{ServerOptions, WorkloadInput};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::MacroConfig;
use impulse::serve::{
    crc32, encode_digits_request, encode_infer_request, encode_stats_request,
    encode_stream_append, hello_payload, serve_tcp, Decoded, Frame, FrameClient, FrameReader,
    PayloadType, ServeCore, TcpServeHandle, WireError, CRC_LEN, MAX_PAYLOAD, PROTOCOL_VERSION,
};
use impulse::snn::SentimentNetwork;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const SEED: u64 = 0xF022_2026;

/// The valid-frame corpus the mutator starts from: every request shape
/// the listener accepts, covering the one-shot, stats, and stream
/// surfaces.
fn corpus() -> Vec<Vec<u8>> {
    vec![
        Frame::new(PayloadType::Hello, 0, hello_payload(1, 1)).encode(),
        Frame::new(PayloadType::InferRequest, 7, encode_infer_request(&[3, 1, 4]).unwrap())
            .encode(),
        Frame::new(
            PayloadType::DigitsInferRequest,
            8,
            encode_digits_request(2, 2, &[0.0, 0.5, 1.0, -1.0]).unwrap(),
        )
        .encode(),
        Frame::new(PayloadType::StatsRequest, 9, encode_stats_request()).encode(),
        Frame::new(PayloadType::StreamOpen, 21, Vec::new()).encode(),
        Frame::new(
            PayloadType::StreamAppend,
            22,
            encode_stream_append(21, &WorkloadInput::Words(vec![3, 1, 4])).unwrap(),
        )
        .encode(),
    ]
}

/// Re-stamp the CRC trailer after a deliberate header/payload edit, so
/// the mutation under test is reached instead of shadowing as BadCrc.
fn fix_crc(bytes: &mut [u8]) {
    let body = bytes.len() - CRC_LEN;
    let crc = crc32(&bytes[..body]);
    bytes[body..].copy_from_slice(&crc.to_be_bytes());
}

/// One seeded mutation of a corpus frame. Returns the mutant and a
/// label for failure messages.
fn mutate(rng: &mut XorShiftRng, base: &[u8]) -> (Vec<u8>, &'static str) {
    let mut b = base.to_vec();
    match rng.gen_range(7) {
        0 => {
            // truncation: cut anywhere inside the frame
            let cut = 1 + rng.gen_range(b.len() as u64 - 1) as usize;
            b.truncate(cut);
            (b, "truncated")
        }
        1 => {
            // single bit flip anywhere (header, payload, or CRC)
            let pos = rng.gen_range(b.len() as u64) as usize;
            b[pos] ^= 1 << rng.gen_range(8);
            (b, "bit-flip")
        }
        2 => {
            // oversized declared length, rejected from the header alone
            let len = (MAX_PAYLOAD as u32) + 1 + rng.gen_range(1 << 20) as u32;
            b[16..20].copy_from_slice(&len.to_be_bytes());
            (b, "oversized-length")
        }
        3 => {
            // corrupted magic
            let pos = rng.gen_range(4) as usize;
            b[pos] = b[pos].wrapping_add(1 + rng.gen_range(255) as u8);
            (b, "bad-magic")
        }
        4 => {
            // unassigned payload-type byte, CRC fixed so the type check
            // itself is what trips
            b[5] = 0x20 + rng.gen_range(0x5F) as u8;
            fix_crc(&mut b);
            (b, "unknown-type")
        }
        5 => {
            // wrong protocol version, CRC fixed
            b[4] = 2 + rng.gen_range(254) as u8;
            fix_crc(&mut b);
            (b, "bad-version")
        }
        _ => {
            // flipped CRC trailer bit
            let pos = b.len() - CRC_LEN + rng.gen_range(CRC_LEN as u64) as usize;
            b[pos] ^= 1 << rng.gen_range(8);
            (b, "flipped-crc")
        }
    }
}

/// The pure codec never panics on a mutant: `Frame::decode` classifies
/// every case as a frame, a need-more, or a typed `WireError`, and the
/// incremental reader terminates on the mutant followed by EOF.
#[test]
fn fuzz_codec_classifies_every_mutant() {
    let corpus = corpus();
    for case in 0..600u64 {
        let case_seed = SEED ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShiftRng::new(case_seed);
        let base = &corpus[rng.gen_range(corpus.len() as u64) as usize];
        let (mutant, label) = mutate(&mut rng, base);

        // one-shot: must return, never panic
        let _ = Frame::decode(&mutant);

        // incremental: must terminate (frame, clean EOF, or error)
        let mut rd = FrameReader::new(std::io::Cursor::new(mutant.clone()));
        for _ in 0..4 {
            match rd.next_frame() {
                Ok(Some(_)) => continue,
                Ok(None) | Err(_) => break,
            }
        }

        // mutants that still decode as a frame must at least re-encode
        // to their own bytes (the codec never "repairs" input):
        // every mutation class edits covered bytes or the CRC itself
        if let Ok(Decoded::Frame(f, _)) = Frame::decode(&mutant) {
            let reencoded = f.encode();
            assert!(
                reencoded == mutant || label == "truncated",
                "case {case} (seed {case_seed:#x}, {label}): \
                 decoded frame does not re-encode to the mutant bytes"
            );
        }
    }
}

fn start_server() -> (Arc<ServeCore>, TcpServeHandle) {
    let a = SentimentArtifacts::synthetic(29);
    let vocab = a.emb_q.len() as i64;
    let core = Arc::new(
        ServeCore::start_with(ServerOptions::default(), vocab, move || {
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

/// Drain a fuzzed connection to EOF. `Err` if the server wedges: a
/// read timeout here means the listener neither answered nor closed.
fn drain(s: &TcpStream) -> Result<Vec<u8>, String> {
    let mut r = s.try_clone().unwrap();
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match r.read(&mut chunk) {
            Ok(0) => return Ok(buf),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err("server wedged: no answer and no close within the read timeout"
                    .to_string())
            }
            // reset/abort while we still hold unread bytes is a close
            Err(_) => return Ok(buf),
        }
    }
}

/// Whatever the server sent back must be well-formed frames — it never
/// emits partial or corrupt bytes, even while rejecting garbage.
fn assert_clean_frames(bytes: &[u8], ctx: &str) {
    let mut rest = bytes;
    while !rest.is_empty() {
        match Frame::decode(rest) {
            Ok(Decoded::Frame(_, used)) => rest = &rest[used..],
            other => panic!("{ctx}: server wrote malformed bytes: {other:?}"),
        }
    }
}

/// Live-listener fuzzing: every mutant connection is answered with an
/// error frame or dropped cleanly (EOF), within the timeout, and the
/// server keeps serving fresh well-formed clients afterwards.
#[test]
fn fuzz_live_listener_never_wedges() {
    let (core, handle) = start_server();
    let addr = handle.local_addr();
    let corpus = corpus();

    for case in 0..48u64 {
        let case_seed = SEED ^ (1 << 32) ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShiftRng::new(case_seed);
        let base = &corpus[rng.gen_range(corpus.len() as u64) as usize];
        let (mutant, label) = mutate(&mut rng, base);

        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = s.try_clone().unwrap();
        // a write error just means the server already rejected and
        // closed — that counts as a clean drop
        let _ = w.write_all(&mutant);
        let _ = s.shutdown(Shutdown::Write);
        let answer = drain(&s).unwrap_or_else(|e| {
            panic!("case {case} (seed {case_seed:#x}, {label}): {e}")
        });
        assert_clean_frames(
            &answer,
            &format!("case {case} (seed {case_seed:#x}, {label})"),
        );
    }

    // the listener survived 48 garbage connections: a fresh client
    // still gets served, and no stream lane leaked
    let mut client = FrameClient::connect(addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
    let p = client.call(&WorkloadInput::Words(vec![1, 2, 3])).unwrap();
    client.wait(&p).expect("server must still serve after fuzzing");
    drop(client);

    assert_eq!(core.streams().active(), 0, "fuzzing leaked a pinned stream lane");
    handle.stop();
    core.shutdown();
}

/// Stream-lane accounting under abuse: a connection that OPENS a real
/// stream and then turns to garbage must still free its lane when the
/// listener drops it — the eviction path, not just the happy-path
/// close.
#[test]
fn fuzzed_connection_with_open_stream_frees_its_lane() {
    let (core, handle) = start_server();
    let addr = handle.local_addr();

    for case in 0..8u64 {
        let case_seed = SEED ^ (2 << 32) ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = XorShiftRng::new(case_seed);

        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = s.try_clone().unwrap();
        // valid open (acked, lane pinned) …
        w.write_all(&Frame::new(PayloadType::StreamOpen, 21, Vec::new()).encode()).unwrap();
        let mut rd = FrameReader::new(s.try_clone().unwrap());
        let ack = rd.next_frame().unwrap().expect("open must be acked");
        assert_eq!(ack.payload_type, PayloadType::StreamAck, "case {case}");
        assert!(core.streams().active() >= 1, "case {case}: lane not pinned");
        // … then garbage on the same connection
        let base = Frame::new(
            PayloadType::StreamAppend,
            22,
            encode_stream_append(21, &WorkloadInput::Words(vec![5])).unwrap(),
        )
        .encode();
        let (mutant, label) = mutate(&mut rng, &base);
        let _ = w.write_all(&mutant);
        let _ = s.shutdown(Shutdown::Write);
        let answer = drain(&s).unwrap_or_else(|e| {
            panic!("case {case} (seed {case_seed:#x}, {label}): {e}")
        });
        assert_clean_frames(
            &answer,
            &format!("case {case} (seed {case_seed:#x}, {label})"),
        );
        // connection teardown must have released the pinned lane; the
        // listener runs close_conn after the reader loop exits, so give
        // the teardown a bounded moment to land
        let mut freed = false;
        for _ in 0..200 {
            if core.streams().active() == 0 {
                freed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(freed, "case {case} (seed {case_seed:#x}, {label}): stream lane leaked");
    }

    handle.stop();
    core.shutdown();
}
