//! Differential tests of the batched digits (conv) path: fused-lane
//! execution must be bit-identical to sequential `run_image` across
//! ragged batch sizes, blank lanes must cost zero AccW2V cycles, and
//! fused batches must never cost more cycles per image than
//! sequential processing (the ISSUE 3 acceptance criteria).

use impulse::bits::XorShiftRng;
use impulse::data::DigitsArtifacts;
use impulse::isa::InstructionKind;
use impulse::macro_sim::MacroConfig;
use impulse::snn::{DigitsNetwork, DigitsResult};

fn rand_images(seed: u64, n: usize) -> Vec<Vec<f32>> {
    let mut rng = XorShiftRng::new(seed);
    (0..n)
        .map(|_| (0..28 * 28).map(|_| rng.gen_f64() as f32).collect())
        .collect()
}

fn net(seed: u64) -> DigitsNetwork {
    let a = DigitsArtifacts::synthetic(seed);
    DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap()
}

fn run_sequential(net: &mut DigitsNetwork, images: &[Vec<f32>]) -> Vec<DigitsResult> {
    images.iter().map(|img| net.run_image(img).unwrap()).collect()
}

/// The flagship differential: batched digits inference must reproduce
/// every image's sequential `v_out` and `pred` exactly, at batch
/// sizes 1, lane-max, and lane-max+1 (which exercises chunking).
#[test]
fn batched_digits_bit_identical_across_ragged_batch_sizes() {
    let seed = 42;
    let mut seq_net = net(seed);
    let mut batch_net = net(seed);
    let max = batch_net.max_batch_lanes();
    assert!(max >= 2, "lane budget must allow real batches, got {max}");
    let images = rand_images(7, max + 1);
    let want = run_sequential(&mut seq_net, &images);
    for bsz in [1usize, max, max + 1] {
        let refs: Vec<&[f32]> = images[..bsz].iter().map(|v| v.as_slice()).collect();
        let got = batch_net.run_images_batched(&refs).unwrap();
        assert_eq!(got.len(), bsz);
        for (i, (g, w)) in got.iter().zip(&want[..bsz]).enumerate() {
            assert_eq!(g.v_out, w.v_out, "batch {bsz} image {i}: potentials diverged");
            assert_eq!(g.pred, w.pred, "batch {bsz} image {i}: prediction diverged");
        }
    }
}

/// A blank image in a lane contributes nothing to the spike unions, so
/// it must cost exactly its solo spend (neuron updates + read-out, no
/// AccW2V) — and must not change the batch's AccW2V count at all.
#[test]
fn blank_lane_costs_zero_accw2v() {
    let seed = 11;
    let images = rand_images(3, 1);
    let blank = vec![0.0f32; 28 * 28];

    let mut solo = net(seed);
    let want_img = solo.run_image(&images[0]).unwrap();
    let want_blank = solo.run_image(&blank).unwrap();

    // solo blank: no synapse fires anywhere
    let mut blank_only = net(seed);
    blank_only.run_image(&blank).unwrap();
    assert_eq!(
        blank_only.stats().histogram.get(&InstructionKind::AccW2V),
        None,
        "a blank image must not fire synapses"
    );

    // batched [img] vs [img, blank]: identical AccW2V spend
    let mut a = net(seed);
    a.run_images_batched(&[&images[0]]).unwrap();
    let acc_one = a.stats().histogram.get(&InstructionKind::AccW2V).copied();
    let mut b = net(seed);
    let got = b.run_images_batched(&[&images[0], &blank]).unwrap();
    let acc_two = b.stats().histogram.get(&InstructionKind::AccW2V).copied();
    assert_eq!(acc_one, acc_two, "a blank lane must add zero AccW2V cycles");

    // honest attribution: each lane pays exactly its solo spend (the
    // lanes share no spiking rows, so no union cycle is split)
    assert_eq!(got[0].v_out, want_img.v_out);
    assert_eq!(got[1].v_out, want_blank.v_out);
    assert_eq!(got[0].cycles, want_img.cycles, "image lane attribution");
    assert_eq!(got[1].cycles, want_blank.cycles, "blank lane attribution");
}

/// The acceptance criterion on cost: fused batches at {1, 4, 16} must
/// spend no more macro cycles per image than sequential runs (the
/// union AccW2V stream can only shrink the issue count), with batch 1
/// exactly equal.
#[test]
fn batched_cycles_per_image_never_exceed_sequential() {
    let seed = 23;
    let images = rand_images(9, 16);
    let mut seq_net = net(seed);
    let seq: Vec<u64> = run_sequential(&mut seq_net, &images)
        .iter()
        .map(|r| r.cycles)
        .collect();
    let mut batch_net = net(seed);
    for bsz in [1usize, 4, 16] {
        let refs: Vec<&[f32]> = images[..bsz].iter().map(|v| v.as_slice()).collect();
        let got = batch_net.run_images_batched(&refs).unwrap();
        let batched: u64 = got.iter().map(|r| r.cycles).sum();
        let sequential: u64 = seq[..bsz].iter().sum();
        assert!(
            batched <= sequential,
            "batch {bsz}: fused {batched} cycles > sequential {sequential}"
        );
        if bsz == 1 {
            assert_eq!(batched, sequential, "a singleton batch pays its solo cost");
        } else {
            assert!(
                batched < sequential,
                "batch {bsz}: random images share spikes — fusion must amortize"
            );
        }
    }
}
