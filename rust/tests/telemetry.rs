//! End-to-end tests of the live telemetry subsystem: a scripted load
//! driven through the TCP serve path must be reflected *exactly* in
//! the `StatsResponse` counters (the PR's acceptance criterion), the
//! backpressure flags word must round-trip for capability-negotiated
//! clients while v1 clients keep seeing all-zero flags, and the
//! Prometheus endpoint must expose the same registry.

// The scripted load drives the original per-workload client calls on
// purpose: pre-stream clients must keep producing identical counters.
#![allow(deprecated)]

use impulse::coordinator::{ServerOptions, WorkloadKind};
use impulse::data::{DigitsArtifacts, SentimentArtifacts};
use impulse::isa::InstructionKind;
use impulse::macro_sim::MacroConfig;
use impulse::serve::{
    decode_backpressure, serve_tcp, ErrorCode, FrameClient, PayloadType, ServeCore,
    TcpServeHandle, CAP_BACKPRESSURE, PROTOCOL_VERSION,
};
use impulse::snn::{DigitsNetwork, SentimentNetwork};
use impulse::telemetry::{serve_metrics, Telemetry, TelemetryConfig, Transport};
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

const VOCAB: i64 = 20; // SentimentArtifacts::synthetic vocabulary

fn start_sentiment(
    seed: u64,
    soft_limit: u64,
) -> (Arc<Telemetry>, Arc<ServeCore>, TcpServeHandle) {
    let tele = Arc::new(Telemetry::new(TelemetryConfig {
        queue_soft_limit: soft_limit,
        ..TelemetryConfig::default()
    }));
    let a = SentimentArtifacts::synthetic(seed);
    let core = Arc::new(
        ServeCore::start_with(
            ServerOptions {
                workers: 2,
                adaptive: true,
                telemetry: Some(Arc::clone(&tele)),
                ..ServerOptions::default()
            },
            VOCAB,
            move || SentimentNetwork::from_artifacts(&a, MacroConfig::fast()),
        )
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (tele, core, handle)
}

fn client(handle: &TcpServeHandle) -> FrameClient {
    let mut c = FrameClient::connect(handle.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c
}

/// The acceptance criterion: drive a scripted load through the TCP
/// serve path, then the `StatsResponse` counters match the load
/// exactly — request counts per workload kind, conserved cycle
/// totals, nonzero energy/EDP, lane occupancy, input-sparsity
/// accounting, drained queue depth — and the Prometheus endpoint
/// exposes the same registry.
#[test]
fn stats_response_matches_scripted_load_exactly() {
    let (tele, core, handle) = start_sentiment(71, 1024);
    let reqs: Vec<Vec<i64>> = vec![
        vec![3, 7, 5],
        vec![19],
        vec![0, 0, 0, 0, 0, 0, 0, 0],
        vec![2, 11, 6],
        vec![1, 2, 3, 4, 5],
    ];
    let total_words: u64 = reqs.iter().map(|r| r.len() as u64).sum();

    let mut c = client(&handle);
    assert_eq!(c.hello().unwrap(), PROTOCOL_VERSION);
    for (i, r) in reqs.iter().enumerate() {
        c.send_infer(i as u64, r).unwrap();
    }
    let mut wire_cycles = 0u64;
    for _ in 0..reqs.len() {
        let (id, res) = c.next_result().unwrap().expect("stream ended early");
        let r = res.unwrap_or_else(|(code, m)| panic!("req {id} failed ({code}): {m}"));
        wire_cycles += r.cycles;
    }

    // the stats fetch rides the same connection; a v1 client (no caps
    // negotiated) must see the all-zero flags word on every frame
    let (snap, flags) = c.fetch_stats(99).unwrap();
    assert_eq!(flags, 0, "v1 clients must keep byte-identical all-zero flags");

    let k = snap.kind(WorkloadKind::Sentiment).unwrap();
    assert_eq!(
        (k.submitted, k.ok, k.err),
        (reqs.len() as u64, reqs.len() as u64, 0),
        "counters must match the scripted load exactly"
    );
    assert_eq!(k.cycles, wire_cycles, "attributed cycles conserved against responses");
    assert!(k.energy_fj > 0, "served load must show nonzero energy");
    assert!(k.edp_js > 0.0, "served load must show nonzero EDP");
    assert_eq!(k.input_units, total_words);
    assert_eq!(k.input_active, total_words, "session-clamped ids are all active");
    let d = snap.kind(WorkloadKind::Digits).unwrap();
    assert_eq!((d.submitted, d.ok, d.err), (0, 0, 0), "no digits load was sent");

    assert_eq!(snap.queue_depth, 0, "the queue drained before the stats fetch");
    assert!(!snap.soft_limited);
    assert_eq!(snap.batch_lanes, reqs.len() as u64, "one lane per request");
    assert!(snap.batches >= 1 && snap.batches <= reqs.len() as u64);
    assert!(snap.batch_lane_capacity >= snap.batch_lanes);
    assert!(
        snap.instr_count(InstructionKind::AccW2V) > 0,
        "AccW2V issue (the spike-proportional work) must be counted"
    );
    let tcp = snap.transport(Transport::Tcp).unwrap();
    assert_eq!(tcp.count, reqs.len() as u64, "one TCP delivery per request");
    assert_eq!(
        tcp.buckets.iter().sum::<u64>(),
        reqs.len() as u64,
        "every delivery lands in a latency bucket"
    );
    let stdio = snap.transport(Transport::Stdio).unwrap();
    assert_eq!(stdio.count, 0, "no stdio traffic in this test");

    // the Prometheus endpoint serves the same registry
    let metrics = serve_metrics("127.0.0.1:0", Arc::clone(&tele)).unwrap();
    let mut s = std::net::TcpStream::connect(metrics.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut page = String::new();
    s.read_to_string(&mut page).unwrap();
    assert!(page.contains(&format!(
        "impulse_requests_submitted_total{{kind=\"sentiment\"}} {}",
        reqs.len()
    )));
    assert!(page.contains("impulse_request_latency_seconds_count{transport=\"tcp\"} 5"));
    metrics.stop();

    c.finish_writes().unwrap();
    assert!(c.next_frame().unwrap().is_none());
    handle.stop();
    core.shutdown();
}

/// The backpressure flags word round-trips: a client that negotiates
/// `CAP_BACKPRESSURE` sees live telemetry flags on response frames
/// (soft-limit bit forced on via the documented `soft_limit = 0`
/// drain mode), while a plain v1 client on the same server keeps
/// receiving all-zero flags.
#[test]
fn backpressure_flag_roundtrips_and_v1_clients_are_untouched() {
    let (_tele, core, handle) = start_sentiment(83, 0);

    let mut negotiated = client(&handle);
    let (version, caps) = negotiated.hello_with_caps(CAP_BACKPRESSURE).unwrap();
    assert_eq!(version, PROTOCOL_VERSION);
    assert_eq!(caps, CAP_BACKPRESSURE, "the server must grant the backpressure cap");

    negotiated.send_infer(1, &[3, 1, 4]).unwrap();
    let f = negotiated.next_frame().unwrap().expect("expected a response frame");
    assert_eq!(f.payload_type, PayloadType::InferResponse);
    let bp = decode_backpressure(f.flags)
        .expect("negotiated client must receive telemetry flags");
    assert!(bp.soft_limited, "soft limit 0 signals unconditionally (drain mode)");

    // the StatsResponse carries the advertisement too, and agrees
    let (snap, flags) = negotiated.fetch_stats(2).unwrap();
    let bp2 = decode_backpressure(flags).expect("stats frame must carry flags");
    assert!(bp2.soft_limited);
    assert!(snap.soft_limited, "snapshot and flags word must agree");
    assert_eq!(snap.queue_soft_limit, 0);

    // a concurrent plain-v1 client sees byte-identical v1 frames
    let mut plain = client(&handle);
    assert_eq!(plain.hello().unwrap(), PROTOCOL_VERSION);
    plain.send_infer(7, &[5, 5]).unwrap();
    let g = plain.next_frame().unwrap().expect("expected a response frame");
    assert_eq!(g.payload_type, PayloadType::InferResponse);
    assert_eq!(g.flags, 0, "non-negotiated clients must never see nonzero flags");

    negotiated.finish_writes().unwrap();
    plain.finish_writes().unwrap();
    assert!(negotiated.next_frame().unwrap().is_none());
    assert!(plain.next_frame().unwrap().is_none());
    handle.stop();
    core.shutdown();
}

/// A malformed (non-empty) StatsRequest errors per request and the
/// connection stays usable for a well-formed one.
#[test]
fn malformed_stats_request_errors_but_connection_survives() {
    use impulse::serve::{decode_error, Frame, FrameReader};
    let (_tele, core, handle) = start_sentiment(5, 1024);
    let mut raw = std::net::TcpStream::connect(handle.local_addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = FrameReader::new(raw.try_clone().unwrap());

    Frame::new(PayloadType::StatsRequest, 4, vec![1]).write_to(&mut raw).unwrap();
    let e = reader.next_frame().unwrap().expect("expected an error frame");
    assert_eq!(e.payload_type, PayloadType::Error);
    assert_eq!(e.request_id, 4);
    let (code, _) = decode_error(&e.payload).unwrap();
    assert_eq!(code, ErrorCode::Malformed.as_u16());

    Frame::new(PayloadType::StatsRequest, 5, vec![]).write_to(&mut raw).unwrap();
    let ok = reader.next_frame().unwrap().expect("connection must survive");
    assert_eq!(ok.payload_type, PayloadType::StatsResponse);
    assert_eq!(ok.request_id, 5);

    raw.shutdown(std::net::Shutdown::Write).unwrap();
    assert!(reader.next_frame().unwrap().is_none());
    handle.stop();
    core.shutdown();
}

/// Digits requests are accounted under their own workload kind, with
/// image pixels driving the input-sparsity counters.
#[test]
fn digits_load_accounted_under_its_own_kind() {
    let tele = Arc::new(Telemetry::default());
    let a = DigitsArtifacts::synthetic(47);
    let imgs: Vec<Vec<f32>> = a.test_x[..2].to_vec();
    let a2 = a.clone();
    let core = Arc::new(
        ServeCore::start_with(
            ServerOptions {
                workers: 1,
                telemetry: Some(Arc::clone(&tele)),
                ..ServerOptions::default()
            },
            1,
            move || DigitsNetwork::from_artifacts(&a2, MacroConfig::fast()),
        )
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    let mut c = client(&handle);
    for (i, img) in imgs.iter().enumerate() {
        c.send_digits_infer(i as u64, 28, 28, img).unwrap();
    }
    for _ in 0..imgs.len() {
        let (_, res) = c.next_digits_result().unwrap().expect("stream ended early");
        res.unwrap_or_else(|(code, m)| panic!("digits request failed ({code}): {m}"));
    }
    let (snap, _) = c.fetch_stats(9).unwrap();
    let d = snap.kind(WorkloadKind::Digits).unwrap();
    assert_eq!((d.submitted, d.ok, d.err), (2, 2, 0));
    assert!(d.cycles > 0 && d.energy_fj > 0);
    assert_eq!(d.input_units, 2 * 28 * 28);
    assert!(d.input_active <= d.input_units);
    let s = snap.kind(WorkloadKind::Sentiment).unwrap();
    assert_eq!(s.submitted, 0);
    c.finish_writes().unwrap();
    handle.stop();
    core.shutdown();
}
