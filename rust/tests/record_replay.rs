//! End-to-end record/replay determinism: a mixed capture (handshake,
//! one-shot sentiment requests, a streaming session, a rejected
//! cross-workload request) taken against a live TCP server must replay
//! bit-identically — same response frames, same V-digest checkpoints —
//! on a fresh core, on BOTH execution engines (the ISSUE's acceptance
//! criterion), and a tampered capture must be flagged as divergent.

use impulse::coordinator::{ServerOptions, WorkloadInput};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::{Engine, MacroConfig};
use impulse::replay::runner::replay_capture;
use impulse::replay::{Capture, Event, Recorder};
use impulse::serve::{serve_tcp, FrameClient, ServeCore, HEADER_LEN, PROTOCOL_VERSION};
use impulse::snn::SentimentNetwork;
use std::sync::Arc;
use std::time::Duration;

const SEED: u64 = 1071;

/// A serve core in the exact shape `impulse serve --record` pins:
/// one worker, no batching, digests captured per request.
fn record_shaped_core(engine: Engine) -> Arc<ServeCore> {
    let a = SentimentArtifacts::synthetic(SEED);
    let vocab = a.emb_q.len() as i64;
    let mac = MacroConfig { engine, ..MacroConfig::default() };
    let opts = ServerOptions {
        workers: 1,
        batch_size: 1,
        capture_digests: true,
        ..ServerOptions::default()
    };
    Arc::new(
        ServeCore::start_with(opts, vocab, move || SentimentNetwork::from_artifacts(&a, mac))
            .unwrap(),
    )
}

/// Drive a mixed-traffic session against a recording server and return
/// the capture: hello, one-shot word requests (including a clamped
/// out-of-range id), a streaming session with a read-out, and an image
/// request the sentiment workload must reject with an error frame.
fn record_session() -> Capture {
    let core = record_shaped_core(Engine::Fast);
    let rec = Arc::new(Recorder::in_memory());
    core.set_recorder(Arc::clone(&rec));
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();

    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);

    for words in [vec![3i64, 7, 5], vec![19], vec![999, -4, 2, 11]] {
        let p = client.call(&WorkloadInput::Words(words)).unwrap();
        client.wait(&p).unwrap();
    }

    let h = client.stream_open().unwrap();
    for chunk in [vec![2i64, 9], vec![14], vec![6, 1, 1]] {
        client.stream_append(&h, &WorkloadInput::Words(chunk)).unwrap();
    }
    client.stream_read_out(&h).unwrap();
    client.stream_close(&h).unwrap();

    // wrong workload kind: answered with an error frame, also recorded
    let p = client
        .call(&WorkloadInput::Image { h: 2, w: 2, pixels: vec![0.0, 0.5, 1.0, -1.0] })
        .unwrap();
    assert!(client.wait(&p).is_err(), "sentiment server must reject an image");

    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none(), "server must close after drain");
    handle.stop();
    core.shutdown();
    rec.capture()
}

/// The acceptance criterion: the capture replays bit-identically on a
/// fresh core with the same engine AND on the bit-level engine (cross-
/// engine equivalence on real recorded traffic).
#[test]
fn mixed_capture_replays_bit_identically_on_both_engines() {
    let capture = record_session();
    let digests = capture
        .events
        .iter()
        .filter(|e| matches!(e, Event::Digest { .. }))
        .count();
    assert!(
        digests >= 8,
        "expected a digest per one-shot + per stream op, got {digests}"
    );

    for engine in [Engine::Fast, Engine::BitLevel] {
        let core = record_shaped_core(engine);
        let report = replay_capture(&capture, &core).unwrap();
        core.shutdown();
        assert_eq!(report.connections, 1, "engine {engine:?}");
        assert!(report.frames_out >= 10, "engine {engine:?}: {report:?}");
        assert_eq!(report.digests, digests, "engine {engine:?}");
        assert!(
            report.is_ok(),
            "engine {engine:?} diverged: {}",
            report.divergence.as_deref().unwrap_or("")
        );
    }
}

/// The capture survives the text format round trip (what `--record`
/// writes and `impulse replay` loads) and still replays clean.
#[test]
fn capture_text_round_trip_replays_clean() {
    let capture = record_session();
    let reloaded = Capture::from_text(&capture.to_text()).unwrap();
    assert_eq!(reloaded.events, capture.events);

    let core = record_shaped_core(Engine::Fast);
    let report = replay_capture(&reloaded, &core).unwrap();
    core.shutdown();
    assert!(report.is_ok(), "{:?}", report.divergence);
}

/// Tamper detection, digest side: flipping one bit of a recorded
/// V-digest must be reported as a divergence, not silently accepted.
#[test]
fn tampered_digest_is_flagged() {
    let mut capture = record_session();
    let slot = capture
        .events
        .iter_mut()
        .find_map(|e| match e {
            Event::Digest { digest, .. } => Some(digest),
            _ => None,
        })
        .expect("capture has digests");
    *slot ^= 1;

    let core = record_shaped_core(Engine::Fast);
    let report = replay_capture(&capture, &core).unwrap();
    core.shutdown();
    let d = report.divergence.expect("flipped digest must diverge");
    assert!(d.contains("digest"), "divergence should name the digest: {d}");
}

/// Tamper detection, frame side: flipping the prediction byte of a
/// recorded `InferResponse` (a byte the normalizer does NOT mask, as
/// latency/batch/worker are) must be reported as a divergence.
#[test]
fn tampered_response_byte_is_flagged() {
    let mut capture = record_session();
    let bytes = capture
        .events
        .iter_mut()
        .find_map(|e| match e {
            Event::FrameOut { bytes, .. } if bytes.len() > HEADER_LEN && bytes[5] == 0x11 => {
                Some(bytes)
            }
            _ => None,
        })
        .expect("capture has an InferResponse frame");
    bytes[HEADER_LEN] ^= 1; // pred byte

    let core = record_shaped_core(Engine::Fast);
    let report = replay_capture(&capture, &core).unwrap();
    core.shutdown();
    assert!(report.divergence.is_some(), "flipped pred byte must diverge");
}
