//! Integration tests of the serve path: every submitted request gets
//! exactly one response — across interleaved submits and drains, empty
//! and clamped word-id edge cases, batching on and off — and the
//! drain bookkeeping cannot wedge on inference errors (the
//! `cli/serve.rs` regression: drains key off received responses, not
//! the inflight counter).

use impulse::coordinator::{InferenceServer, Request, ServerOptions};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::MacroConfig;
use impulse::snn::SentimentNetwork;
use std::collections::HashMap;
use std::time::Duration;

fn factory(
    seed: u64,
) -> impl Fn() -> impulse::Result<SentimentNetwork> + Send + Sync + 'static {
    move || {
        let a = SentimentArtifacts::synthetic(seed);
        SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
    }
}

/// Mimic the `impulse serve` line loop: submit, opportunistically drain
/// ready responses, then drain the remainder by received count.
fn serve_like_loop(
    server: &InferenceServer,
    reqs: Vec<Request>,
) -> Vec<impulse::coordinator::Response> {
    let mut pending = 0u64;
    let mut responses = Vec::new();
    for req in reqs {
        server.submit(req).unwrap();
        pending += 1;
        while let Some(r) = server.try_recv() {
            pending -= 1;
            responses.push(r);
        }
    }
    while pending > 0 {
        let r = server.recv().unwrap();
        pending -= 1;
        responses.push(r);
    }
    responses
}

fn check_exactly_one_response_each(opts: ServerOptions, n: u64) {
    let server = InferenceServer::start_with(opts, factory(42)).unwrap();
    // interleaved shapes: normal, single-word, long, clamped-at-edge
    // vocab ids (the synthetic vocab is 20), and an out-of-range id
    // that must come back as an error response rather than vanish.
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            Request::words(
                i,
                match i % 5 {
                    0 => vec![(i as i64) % 20, 3, 5],
                    1 => vec![19], // last valid id (clamp target)
                    2 => vec![0, 0, 0, 0, 0, 0, 0, 0],
                    3 => vec![(i as i64) % 20, -1, 7], // padding mid-request
                    _ => vec![999], // out of range → error response
                },
            )
        })
        .collect();
    let responses = serve_like_loop(&server, reqs);
    assert_eq!(responses.len(), n as usize, "one response per request");
    let mut seen: HashMap<u64, u32> = HashMap::new();
    for r in &responses {
        *seen.entry(r.id).or_insert(0) += 1;
        if r.id % 5 == 4 {
            assert!(r.err.is_some(), "id {} must error (word id 999)", r.id);
        } else {
            assert!(r.err.is_none(), "id {} unexpectedly failed: {:?}", r.id, r.err);
        }
    }
    for i in 0..n {
        assert_eq!(seen.get(&i), Some(&1), "id {i} must answer exactly once");
    }
    assert_eq!(server.inflight(), 0);
    server.shutdown();
}

#[test]
fn every_id_answered_once_unbatched() {
    check_exactly_one_response_each(
        ServerOptions {
            workers: 3,
            ..ServerOptions::default()
        },
        25,
    );
}

#[test]
fn every_id_answered_once_batched() {
    check_exactly_one_response_each(
        ServerOptions {
            workers: 2,
            batch_size: 8,
            batch_deadline: Duration::from_millis(5),
            ..ServerOptions::default()
        },
        25,
    );
}

#[test]
fn every_id_answered_once_adaptive() {
    check_exactly_one_response_each(
        ServerOptions {
            workers: 2,
            adaptive: true,
            ..ServerOptions::default()
        },
        25,
    );
}

#[test]
fn every_id_answered_once_pipelined() {
    check_exactly_one_response_each(
        ServerOptions {
            workers: 2,
            pipeline: true,
            ..ServerOptions::default()
        },
        10,
    );
}

/// Batched and unbatched serving must agree bit-for-bit on every
/// well-formed request (the differential form of the tentpole).
#[test]
fn batched_serving_matches_unbatched() {
    let reqs: Vec<Request> = (0..30)
        .map(|i| {
            Request::words(i, vec![(i as i64) % 20, (7 * i as i64) % 20, 11, (3 * i as i64) % 20])
        })
        .collect();
    let plain = InferenceServer::start(2, factory(7)).unwrap();
    let (want, _) = plain.run_batch(reqs.clone()).unwrap();
    plain.shutdown();

    let batched = InferenceServer::start_with(
        ServerOptions {
            workers: 2,
            batch_size: 16,
            batch_deadline: Duration::from_millis(10),
            ..ServerOptions::default()
        },
        factory(7),
    )
    .unwrap();
    let (got, _) = batched.run_batch(reqs).unwrap();
    batched.shutdown();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.pred, w.pred, "id {}", g.id);
        assert_eq!(g.v_out, w.v_out, "id {}: batched vs unbatched v_out", g.id);
        assert!(g.err.is_none());
    }
}

/// The old serve loop compared `inflight() < pending` to decide when to
/// drain, which wedges when a response is delayed; the rewritten loop
/// must finish even when all responses arrive after the last submit.
#[test]
fn drain_completes_when_responses_lag_submits() {
    let server = InferenceServer::start_with(
        ServerOptions {
            workers: 1,
            batch_size: 4,
            // long deadline: responses intentionally lag the submits
            batch_deadline: Duration::from_millis(50),
            ..ServerOptions::default()
        },
        factory(3),
    )
    .unwrap();
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request::words(i, vec![(i as i64) % 20]))
        .collect();
    let responses = serve_like_loop(&server, reqs);
    assert_eq!(responses.len(), 6);
    assert_eq!(server.inflight(), 0);
    server.shutdown();
}
