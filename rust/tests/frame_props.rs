//! Property tests for the *incremental* frame reader: however a byte
//! stream is fragmented or coalesced by the transport, `FrameReader`
//! must decode exactly the frames a one-shot `Frame::decode` loop sees
//! over the whole buffer — and the error paths (1 MiB cap, CRC
//! failure, mid-frame EOF) must surface the right `WireError` without
//! wedging the reader.

use impulse::proptest_lite::forall_ctx;
use impulse::serve::{
    encode_infer_request, error_payload, Decoded, ErrorCode, Frame, FrameReader, PayloadType,
    WireError, CRC_LEN, HEADER_LEN, MAX_PAYLOAD,
};
use std::io::Read;

/// A `Read` that hands back the stream in pre-cut chunks, one chunk
/// per `read` call (never more than one chunk even if the caller's
/// buffer is larger) — the worst-case short-read transport.
struct Chunked {
    chunks: Vec<Vec<u8>>,
    idx: usize,
    off: usize,
}

impl Chunked {
    fn new(data: &[u8], cuts: &[usize]) -> Chunked {
        let mut chunks = Vec::new();
        let mut prev = 0;
        for &c in cuts {
            let c = c.min(data.len());
            if c > prev {
                chunks.push(data[prev..c].to_vec());
                prev = c;
            }
        }
        if prev < data.len() {
            chunks.push(data[prev..].to_vec());
        }
        Chunked { chunks, idx: 0, off: 0 }
    }
}

impl Read for Chunked {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        while self.idx < self.chunks.len() {
            let chunk = &self.chunks[self.idx];
            if self.off < chunk.len() {
                let n = buf.len().min(chunk.len() - self.off);
                buf[..n].copy_from_slice(&chunk[self.off..self.off + n]);
                self.off += n;
                if self.off == chunk.len() {
                    self.idx += 1;
                    self.off = 0;
                }
                return Ok(n);
            }
            self.idx += 1;
            self.off = 0;
        }
        Ok(0)
    }
}

/// Ground truth: decode the whole buffer with one-shot `Frame::decode`.
fn decode_all(mut bytes: &[u8]) -> Vec<Frame> {
    let mut out = Vec::new();
    while !bytes.is_empty() {
        match Frame::decode(bytes).expect("ground-truth stream is valid") {
            Decoded::Frame(f, used) => {
                out.push(f);
                bytes = &bytes[used..];
            }
            other => panic!("ground-truth stream incomplete: {other:?}"),
        }
    }
    out
}

/// Drain a reader to EOF, collecting frames.
fn read_all<R: Read>(mut rd: FrameReader<R>) -> Vec<Frame> {
    let mut out = Vec::new();
    while let Some(f) = rd.next_frame().expect("valid stream must decode") {
        out.push(f);
    }
    out
}

/// The pinned PROTOCOL.md §6 worked-example frames, as a wire stream.
fn pinned_stream() -> Vec<u8> {
    let frames = [
        Frame::new(PayloadType::InferRequest, 7, encode_infer_request(&[3, 1, 4]).unwrap()),
        Frame::new(PayloadType::Hello, 0, vec![1, 1]),
        Frame::new(
            PayloadType::Error,
            9,
            error_payload(ErrorCode::InferenceFailed, "word id out of range"),
        ),
        Frame::new(PayloadType::StreamOpen, 21, Vec::new()),
    ];
    let mut wire = Vec::new();
    for f in &frames {
        wire.extend_from_slice(&f.encode());
    }
    wire
}

/// Exhaustive: the pinned stream split at EVERY single byte boundary
/// decodes identically to the one-shot decode.
#[test]
fn pinned_frames_split_at_every_byte_boundary() {
    let wire = pinned_stream();
    let want = decode_all(&wire);
    assert_eq!(want.len(), 4);
    for cut in 1..wire.len() {
        let got = read_all(FrameReader::new(Chunked::new(&wire, &[cut])));
        assert_eq!(got, want, "split at byte {cut} changed the decode");
    }
}

/// Property: random multi-frame streams under random fragmentation
/// (including 1-byte trickles and cuts inside headers, payloads, and
/// CRC trailers) decode identically to the one-shot decode.
#[test]
fn prop_random_fragmentation_matches_one_shot() {
    let types = [
        PayloadType::Hello,
        PayloadType::InferRequest,
        PayloadType::InferResponse,
        PayloadType::StreamAppend,
        PayloadType::Error,
    ];
    forall_ctx(
        150,
        0xF4A6,
        |rng| {
            let n_frames = 1 + rng.gen_range(5) as usize;
            let mut wire = Vec::new();
            for _ in 0..n_frames {
                let ty = types[rng.gen_range(types.len() as u64) as usize];
                let len = rng.gen_range(120) as usize;
                let payload: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
                wire.extend_from_slice(&Frame::new(ty, rng.next_u64(), payload).encode());
            }
            let n_cuts = rng.gen_range(12) as usize;
            let mut cuts: Vec<usize> =
                (0..n_cuts).map(|_| 1 + rng.gen_range(wire.len() as u64 - 1) as usize).collect();
            cuts.sort_unstable();
            cuts.dedup();
            (wire, cuts)
        },
        |(wire, cuts)| {
            let want = decode_all(wire);
            let got = read_all(FrameReader::new(Chunked::new(wire, cuts)));
            if got == want {
                Ok(())
            } else {
                Err(format!("fragmented decode differs: {} vs {} frames", got.len(), want.len()))
            }
        },
    );
}

/// Property: frames arriving COALESCED (several frames per read, plus
/// a trailing partial that completes later) decode identically too —
/// the carry buffer must handle more-than-one-frame chunks.
#[test]
fn prop_coalesced_chunks_match_one_shot() {
    forall_ctx(
        100,
        0xC0A7,
        |rng| {
            let n_frames = 2 + rng.gen_range(4) as usize;
            let mut wire = Vec::new();
            for _ in 0..n_frames {
                let len = rng.gen_range(60) as usize;
                let payload: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
                wire.extend_from_slice(
                    &Frame::new(PayloadType::InferRequest, rng.next_u64(), payload).encode(),
                );
            }
            // one cut mid-frame, so some read returns 2+ whole frames
            // plus a partial frame that a later read completes
            let cut = 1 + rng.gen_range(wire.len() as u64 - 1) as usize;
            (wire, vec![cut])
        },
        |(wire, cuts)| {
            let want = decode_all(wire);
            let got = read_all(FrameReader::new(Chunked::new(wire, cuts)));
            if got == want {
                Ok(())
            } else {
                Err("coalesced decode differs from one-shot".to_string())
            }
        },
    );
}

/// The 1 MiB payload cap: a header claiming `MAX_PAYLOAD + 1` is
/// rejected with `Oversized` as soon as the header is complete — even
/// when it arrives a byte at a time — and the reader stays in its
/// error state (deterministic error, no hang, no panic) instead of
/// waiting for a payload that will never be accepted.
#[test]
fn oversized_header_errors_incrementally_without_wedging() {
    let mut bytes = Frame::new(PayloadType::InferRequest, 3, vec![0; 4]).encode();
    bytes[16..20].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_be_bytes());
    let cuts: Vec<usize> = (1..bytes.len()).collect();
    let mut rd = FrameReader::new(Chunked::new(&bytes, &cuts));
    assert!(matches!(rd.next_frame(), Err(WireError::Oversized(n)) if n == MAX_PAYLOAD + 1));
    // the poisoned buffer keeps reporting the same error on re-poll
    assert!(matches!(rd.next_frame(), Err(WireError::Oversized(_))));
}

/// CRC failure under fragmentation: a payload-byte flip surfaces as
/// `BadCrc` once the full frame is buffered, for every split point.
#[test]
fn crc_failure_is_reported_at_every_split_point() {
    let f = Frame::new(PayloadType::InferRequest, 11, encode_infer_request(&[5, 6]).unwrap());
    let mut bytes = f.encode();
    bytes[HEADER_LEN + 2] ^= 0x40;
    for cut in 1..bytes.len() {
        let mut rd = FrameReader::new(Chunked::new(&bytes, &[cut]));
        assert!(
            matches!(rd.next_frame(), Err(WireError::BadCrc { .. })),
            "split at {cut} did not surface BadCrc"
        );
    }
}

/// Property: EOF placement is always classified correctly — a stream
/// cut at a frame boundary ends with `Ok(None)`, a stream cut mid-
/// frame ends with `Truncated`, whatever the fragmentation before it.
#[test]
fn prop_eof_classification() {
    forall_ctx(
        120,
        0xE0F5,
        |rng| {
            let len = rng.gen_range(40) as usize;
            let payload: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
            let a = Frame::new(PayloadType::InferRequest, 1, payload).encode();
            let b = Frame::new(PayloadType::Hello, 2, vec![1, 1]).encode();
            let mut wire = a.clone();
            wire.extend_from_slice(&b);
            // cut anywhere in the stream; at a.len() or wire.len() the
            // EOF is clean, anywhere else it is mid-frame
            let cut = 1 + rng.gen_range(wire.len() as u64) as usize;
            let frag = 1 + rng.gen_range(cut as u64) as usize;
            (wire, a.len(), cut, frag)
        },
        |(wire, boundary, cut, frag)| {
            let mut rd = FrameReader::new(Chunked::new(&wire[..*cut], &[*frag]));
            let clean = *cut == *boundary || *cut == wire.len();
            loop {
                match rd.next_frame() {
                    Ok(Some(_)) => continue,
                    Ok(None) if clean => return Ok(()),
                    Err(WireError::Truncated) if !clean => return Ok(()),
                    other => {
                        return Err(format!(
                            "cut {cut} (boundary {boundary}): got {other:?}, clean={clean}"
                        ))
                    }
                }
            }
        },
    );
}

/// A frame carrying exactly `MAX_PAYLOAD` bytes decodes fine through
/// the incremental reader (the cap is a strict `>` bound), fragmented
/// across several large chunks.
#[test]
fn max_size_frame_passes_incrementally() {
    let f = Frame::new(PayloadType::Error, 2, vec![0xAB; MAX_PAYLOAD]);
    let wire = f.encode();
    assert_eq!(wire.len(), HEADER_LEN + MAX_PAYLOAD + CRC_LEN);
    let cuts = [10, 1000, 300_000, 900_000];
    let got = read_all(FrameReader::new(Chunked::new(&wire, &cuts)));
    assert_eq!(got, vec![f]);
}
