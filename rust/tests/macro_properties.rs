//! Property-based integration tests: randomized invariants over the
//! full mapping + macro + scheduler stack (proptest_lite harness).

use impulse::bitcell::Parity;
use impulse::bits::{wrap11, XorShiftRng};
use impulse::isa::{Instruction, WriteMaskMode};
use impulse::macro_sim::{ComparatorMode, ImpulseMacro, MacroConfig};
use impulse::neuron::{GoldenLayer, NeuronParams};
use impulse::proptest_lite::{forall_ctx, gen};
use impulse::snn::{FcLayer, LayerParams};

/// The flagship differential property: for random layers, random spike
/// trains, and every neuron type, the mapped macro (fast engine) agrees
/// with the functional golden model on every timestep.
#[test]
fn prop_mapped_layer_equals_golden_model() {
    forall_ctx(
        40,
        0xA11CE,
        |rng| {
            let m = 1 + rng.gen_range(128) as usize;
            let n = 1 + rng.gen_range(36) as usize;
            let w = gen::weight_matrix(rng, m, n);
            let neuron = match rng.gen_range(3) {
                0 => LayerParams::if_(rng.gen_i64(1, 400)),
                1 => LayerParams::lif(rng.gen_i64(1, 400), rng.gen_i64(0, 8)),
                _ => LayerParams::rmp(rng.gen_i64(1, 400)),
            };
            let steps: Vec<Vec<bool>> = (0..12)
                .map(|_| {
                    let p = rng.gen_f64();
                    gen::spikes(rng, m, p)
                })
                .collect();
            (w, neuron, steps)
        },
        |(w, neuron, steps)| {
            let mut layer = FcLayer::new(w, *neuron, MacroConfig::fast())
                .map_err(|e| e.to_string())?;
            let mut golden = GoldenLayer::new(
                NeuronParams {
                    neuron: neuron.neuron,
                    threshold: neuron.threshold,
                    reset: neuron.reset,
                    leak: neuron.leak,
                },
                w.clone(),
            );
            for (t, spikes) in steps.iter().enumerate() {
                let got = layer.step(spikes).map_err(|e| e.to_string())?.to_vec();
                let want = golden.step(spikes);
                if got != want {
                    return Err(format!("spike mismatch at t={t}"));
                }
                let gv = layer.potentials().map_err(|e| e.to_string())?;
                if gv != golden.potentials() {
                    return Err(format!("V mismatch at t={t}"));
                }
            }
            Ok(())
        },
    );
}

/// Bit-level vs fast engine on random raw instruction streams —
/// heavier-weight version of the lib test, across random geometry.
#[test]
fn prop_lockstep_engines_never_diverge() {
    forall_ctx(
        10,
        0x10C4,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = XorShiftRng::new(seed);
            let mut m = ImpulseMacro::new(MacroConfig::lockstep());
            for r in 0..8 {
                let mut w = [0i64; 12];
                for x in w.iter_mut() {
                    *x = rng.gen_i64(-32, 31);
                }
                m.write_weights(r, &w).map_err(|e| e.to_string())?;
            }
            for r in 0..6 {
                let p = if r % 2 == 0 { Parity::Odd } else { Parity::Even };
                let mut v = [0i64; 6];
                for x in v.iter_mut() {
                    *x = rng.gen_i64(-1024, 1023);
                }
                m.write_v(r, p, &v).map_err(|e| e.to_string())?;
            }
            for _ in 0..400 {
                let parity = if rng.gen_bool(0.5) { Parity::Odd } else { Parity::Even };
                let vrow = |rng: &mut XorShiftRng| {
                    let base = rng.gen_range(3) as usize * 2;
                    match parity {
                        Parity::Odd => base,
                        Parity::Even => base + 1,
                    }
                };
                let instr = match rng.gen_range(4) {
                    0 => Instruction::AccW2V {
                        w_row: rng.gen_range(8) as usize,
                        v_src: vrow(&mut rng),
                        v_dst: vrow(&mut rng),
                        parity,
                    },
                    1 => {
                        let a = vrow(&mut rng);
                        let b = (a + 2) % 6;
                        Instruction::AccV2V {
                            src_a: a,
                            src_b: b,
                            dst: vrow(&mut rng),
                            parity,
                            mask: if rng.gen_bool(0.5) {
                                WriteMaskMode::All
                            } else {
                                WriteMaskMode::Spiked
                            },
                        }
                    }
                    2 => {
                        let a = vrow(&mut rng);
                        let b = (a + 4) % 6;
                        Instruction::SpikeCheck {
                            v_row: a,
                            thr_row: b,
                            parity,
                        }
                    }
                    _ => Instruction::ResetV {
                        reset_row: vrow(&mut rng),
                        dst: vrow(&mut rng),
                        parity,
                    },
                };
                // Lockstep mode bails with an error on any divergence.
                m.execute(&instr).map_err(|e| e.to_string())?;
            }
            Ok(())
        },
    );
}

/// Sparsity ⇒ work proportionality at the macro level: doubling the
/// spike count exactly doubles the AccW2V count.
#[test]
fn prop_energy_proportional_to_activity() {
    forall_ctx(
        30,
        0x59A1,
        |rng| {
            let m = 16 + rng.gen_range(112) as usize;
            let w = gen::weight_matrix(rng, m, 12);
            let k = 1 + rng.gen_range((m / 2) as u64) as usize;
            (w, m, k)
        },
        |(w, m, k)| {
            let run = |n_spikes: usize| -> Result<u64, String> {
                let mut layer = FcLayer::new(w, LayerParams::rmp(100), MacroConfig::fast())
                    .map_err(|e| e.to_string())?;
                let mut spikes = vec![false; *m];
                for s in spikes.iter_mut().take(n_spikes) {
                    *s = true;
                }
                layer.step(&spikes).map_err(|e| e.to_string())?;
                Ok(layer
                    .stats()
                    .histogram
                    .get(&impulse::isa::InstructionKind::AccW2V)
                    .copied()
                    .unwrap_or(0))
            };
            let half = run(*k)?;
            let full = run(2 * k)?;
            if full != 2 * half {
                return Err(format!("AccW2V {full} != 2×{half}"));
            }
            Ok(())
        },
    );
}

/// The wraparound algebra: accumulating any weight sequence through the
/// macro equals wrap11 of the plain integer sum.
#[test]
fn prop_accumulation_is_mod_2048_sum() {
    forall_ctx(
        30,
        0xACC,
        |rng| {
            let steps = 1 + rng.gen_range(60) as usize;
            (0..steps)
                .map(|_| rng.gen_i64(-32, 31))
                .collect::<Vec<i64>>()
        },
        |ws| {
            let mut m = ImpulseMacro::new(MacroConfig::fast());
            m.write_v(0, Parity::Odd, &[0; 6]).map_err(|e| e.to_string())?;
            let mut expect = 0i64;
            for &w in ws {
                m.write_weights(0, &[w; 12]).map_err(|e| e.to_string())?;
                m.execute(&Instruction::AccW2V {
                    w_row: 0,
                    v_src: 0,
                    v_dst: 0,
                    parity: Parity::Odd,
                })
                .map_err(|e| e.to_string())?;
                expect = wrap11(expect + w);
            }
            let got = m.read_v(0, Parity::Odd).map_err(|e| e.to_string())?;
            if got != [expect; 6] {
                return Err(format!("got {got:?}, expect {expect}"));
            }
            Ok(())
        },
    );
}

/// SignBit comparator always equals the signed (wrapped) comparison.
#[test]
fn prop_comparator_signbit_is_signed_compare() {
    forall_ctx(
        200,
        0xC093,
        |rng| (rng.gen_i64(-1024, 1023), rng.gen_i64(1, 512)),
        |&(v, theta)| {
            let mut m = ImpulseMacro::new(
                MacroConfig::fast().with_comparator(ComparatorMode::SignBit),
            );
            m.write_v(0, Parity::Odd, &[v; 6]).map_err(|e| e.to_string())?;
            m.write_v(1, Parity::Odd, &[-theta; 6]).map_err(|e| e.to_string())?;
            let out = m
                .execute(&Instruction::SpikeCheck {
                    v_row: 0,
                    thr_row: 1,
                    parity: Parity::Odd,
                })
                .map_err(|e| e.to_string())?;
            let want = wrap11(v - theta) >= 0;
            if out.spikes.unwrap() != [want; 6] {
                return Err(format!("v={v} θ={theta}"));
            }
            Ok(())
        },
    );
}
