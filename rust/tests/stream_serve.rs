//! End-to-end tests of streaming stateful sessions over the TCP
//! front-end: membrane state pinned to a stream id must make chunked
//! appends bit-identical to the one-shot path at *every* split point
//! (the PR's acceptance criterion), eviction must free lanes on TTL
//! expiry / connection EOF / the session cap, a shutdown drain must
//! never wedge on abandoned sessions, and the client's adaptive pacer
//! must react to soft-limit advertisements.

use impulse::coordinator::{ServerOptions, WorkloadInput, WorkloadOutput};
use impulse::data::{DigitsArtifacts, SentimentArtifacts};
use impulse::macro_sim::MacroConfig;
use impulse::serve::{
    encode_backpressure, serve_tcp, ErrorCode, Frame, FrameClient, FrameReader, PayloadType,
    ServeCore, ServerError, TcpServeHandle, WirePayload, WireResponse, CAP_BACKPRESSURE,
    PROTOCOL_VERSION,
};
use impulse::snn::{DigitsNetwork, SentimentNetwork};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

const VOCAB: i64 = 20; // SentimentArtifacts::synthetic vocabulary

fn start_core(seed: u64, opts: ServerOptions) -> (Arc<ServeCore>, TcpServeHandle) {
    let a = SentimentArtifacts::synthetic(seed);
    let core = Arc::new(
        ServeCore::start_with(opts, VOCAB, move || {
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

fn connect(handle: &TcpServeHandle) -> FrameClient {
    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
    client
}

fn words(ids: &[i64]) -> WorkloadInput {
    WorkloadInput::Words(ids.to_vec())
}

fn stream_code(e: &anyhow::Error) -> ErrorCode {
    e.downcast_ref::<ServerError>()
        .unwrap_or_else(|| panic!("expected a ServerError, got: {e:#}"))
        .error_code()
        .expect("server sent an unknown error code")
}

/// The tentpole acceptance criterion, sentiment: for *every* split
/// point of a review, appending the two chunks to a pinned stream and
/// reading out is bit-identical (pred, v_out, cycles) to the one-shot
/// request on the same connection — and so is fully word-by-word
/// streaming.
#[test]
fn sentiment_stream_matches_one_shot_at_every_split() {
    // out-of-range ids included: the stream path must apply the same
    // [0, VOCAB) clamp the one-shot submit path does
    let seed = 71;
    let ids: Vec<i64> = vec![3, 7, 999, -5, 0, 12, 19, 4];
    let a = SentimentArtifacts::synthetic(seed);
    let mut solo = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let clamped: Vec<i64> = ids.iter().map(|&w| w.clamp(0, VOCAB - 1)).collect();
    let want = solo.run_review(&clamped).unwrap();

    let (core, handle) = start_core(seed, ServerOptions::default());
    let mut client = connect(&handle);

    // the one-shot serve path agrees with the solo ground truth
    let p = client.call(&words(&ids)).unwrap();
    let oneshot = client.wait(&p).unwrap();
    assert_eq!((oneshot.pred, oneshot.v_out), (want.pred, want.v_out), "one-shot vs solo");

    for split in 1..ids.len() {
        let h = client.stream_open().unwrap();
        let a1 = client.stream_append(&h, &words(&ids[..split])).unwrap();
        let a2 = client.stream_append(&h, &words(&ids[split..])).unwrap();
        assert!(
            a2.cycles > a1.cycles,
            "split {split}: append acks must report cumulative cycles"
        );
        let out = client.stream_read_out(&h).unwrap();
        assert_eq!(
            (out.pred, out.v_out, out.cycles),
            (want.pred, want.v_out, want.cycles),
            "split at word {split}: streamed ≠ one-shot"
        );
        let fin = client.stream_close(&h).unwrap();
        assert!(fin.cycles > 0, "split {split}: close ack lost the cycle total");
    }

    // fully incremental: one word per append
    let h = client.stream_open().unwrap();
    for w in &ids {
        client.stream_append(&h, &words(&[*w])).unwrap();
    }
    let out = client.stream_read_out(&h).unwrap();
    assert_eq!(
        (out.pred, out.v_out, out.cycles),
        (want.pred, want.v_out, want.cycles),
        "word-by-word streamed ≠ one-shot"
    );
    client.stream_close(&h).unwrap();

    handle.stop();
    core.shutdown();
}

/// The tentpole acceptance criterion, digits: appending the image
/// frame once per membrane timestep reproduces the one-shot
/// `run_image` bit-for-bit, both against a solo network and against
/// the one-shot serve path.
#[test]
fn digits_stream_matches_one_shot_per_timestep() {
    let a = DigitsArtifacts::synthetic(47);
    let mut solo = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let t = solo.t;
    let img = a.test_x[0].clone();
    let want = solo.run_image(&img).unwrap();

    let a2 = a.clone();
    let core = Arc::new(
        ServeCore::start_with(ServerOptions::default(), 1, move || {
            DigitsNetwork::from_artifacts(&a2, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    let mut client = connect(&handle);

    let input = WorkloadInput::Image { h: 28, w: 28, pixels: img.clone() };
    let p = client.call(&input).unwrap();
    let oneshot: WorkloadOutput = client.wait(&p).unwrap();
    assert_eq!(oneshot.pred, want.pred, "one-shot serve vs solo prediction");
    assert_eq!(oneshot.v_all, want.v_out, "one-shot serve vs solo potentials");

    let h = client.stream_open().unwrap();
    for step in 0..t {
        let ack = client.stream_append(&h, &input).unwrap();
        assert!(ack.cycles > 0, "timestep {step}: no cost attributed");
    }
    let out = client.stream_read_out(&h).unwrap();
    assert_eq!(
        (out.pred, &out.v_all, out.cycles),
        (want.pred, &want.v_out, want.cycles),
        "per-timestep streamed ≠ one-shot"
    );
    client.stream_close(&h).unwrap();

    handle.stop();
    core.shutdown();
}

/// TTL expiry: an idle stream is evicted, later operations on it are
/// answered with `StreamExpired` (code 11), and the freed lane is
/// reusable by a fresh open.
#[test]
fn idle_stream_expires_and_frees_its_lane() {
    let (core, handle) = start_core(5, ServerOptions {
        max_streams: 1,
        stream_ttl: Duration::from_millis(25),
        ..ServerOptions::default()
    });
    let mut client = connect(&handle);

    let h = client.stream_open().unwrap();
    client.stream_append(&h, &words(&[3])).unwrap();
    std::thread::sleep(Duration::from_millis(80));

    let err = client.stream_append(&h, &words(&[4])).unwrap_err();
    assert_eq!(stream_code(&err), ErrorCode::StreamExpired);
    // the connection survives a stream error, and the lane is free
    // again: with max_streams = 1 this open only succeeds post-evict
    let h2 = client.stream_open().unwrap();
    client.stream_append(&h2, &words(&[3])).unwrap();
    assert!(client.stream_read_out(&h2).unwrap().cycles > 0);
    client.stream_close(&h2).unwrap();

    let s = core.telemetry().stream_stats();
    assert!(s.expired >= 1, "eviction must be counted: {s:?}");
    handle.stop();
    core.shutdown();
}

/// The session cap: the N+1th concurrent open is refused with
/// `StreamLimit` (code 12); closing one stream frees a slot.
#[test]
fn stream_cap_rejects_excess_opens() {
    let seed = 9;
    let a = SentimentArtifacts::synthetic(seed);
    let mut solo = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let w1 = solo.run_review(&[2]).unwrap();
    let w2 = solo.run_review(&[9, 9]).unwrap();

    let (core, handle) = start_core(seed, ServerOptions {
        max_streams: 2,
        ..ServerOptions::default()
    });
    let mut client = connect(&handle);

    let h1 = client.stream_open().unwrap();
    let h2 = client.stream_open().unwrap();
    let err = client.stream_open().unwrap_err();
    assert_eq!(stream_code(&err), ErrorCode::StreamLimit);

    // both live streams still work, each bit-identical to its own
    // solo run — interleaved appends never leak across lanes
    client.stream_append(&h1, &words(&[2])).unwrap();
    client.stream_append(&h2, &words(&[9])).unwrap();
    client.stream_append(&h2, &words(&[9])).unwrap();
    let o1 = client.stream_read_out(&h1).unwrap();
    let o2 = client.stream_read_out(&h2).unwrap();
    assert_eq!((o1.pred, o1.v_out, o1.cycles), (w1.pred, w1.v_out, w1.cycles), "h1 vs solo");
    assert_eq!((o2.pred, o2.v_out, o2.cycles), (w2.pred, w2.v_out, w2.cycles), "h2 vs solo");

    client.stream_close(&h1).unwrap();
    let h3 = client.stream_open().unwrap();
    client.stream_close(&h3).unwrap();
    client.stream_close(&h2).unwrap();

    let s = core.telemetry().stream_stats();
    assert_eq!((s.opened, s.rejected, s.active), (3, 1, 0), "{s:?}");
    handle.stop();
    core.shutdown();
}

/// Streams are keyed per connection: another client cannot read or
/// close a stream it does not own, even knowing its id.
#[test]
fn streams_are_scoped_to_their_connection() {
    let (core, handle) = start_core(13, ServerOptions::default());
    let mut owner = connect(&handle);
    let mut intruder = connect(&handle);

    let h = owner.stream_open().unwrap();
    owner.stream_append(&h, &words(&[7])).unwrap();

    let err = intruder.stream_read_out(&h).unwrap_err();
    assert_eq!(stream_code(&err), ErrorCode::StreamExpired);
    let err = intruder.stream_close(&h).unwrap_err();
    assert_eq!(stream_code(&err), ErrorCode::StreamExpired);

    // the owner's session is untouched by the failed intrusion
    assert!(owner.stream_read_out(&h).unwrap().cycles > 0);
    owner.stream_close(&h).unwrap();
    handle.stop();
    core.shutdown();
}

/// Abandoned sessions: a client that vanishes without closing its
/// streams releases them on connection EOF, and a stop/drain with
/// recently-pinned lanes completes without wedging.
#[test]
fn abandoned_streams_are_released_and_drain_never_wedges() {
    let (core, handle) = start_core(17, ServerOptions::default());
    {
        let mut client = connect(&handle);
        let h1 = client.stream_open().unwrap();
        let h2 = client.stream_open().unwrap();
        client.stream_append(&h1, &words(&[3])).unwrap();
        client.stream_append(&h2, &words(&[5])).unwrap();
        assert_eq!(core.streams().active(), 2);
        // drop without stream_close: the socket close is the only signal
    }
    let gone_by = Instant::now() + Duration::from_secs(30);
    while core.streams().active() > 0 {
        assert!(Instant::now() < gone_by, "connection EOF never released its streams");
        std::thread::sleep(Duration::from_millis(5));
    }
    let s = core.telemetry().stream_stats();
    assert_eq!((s.opened, s.closed, s.active), (2, 2, 0), "{s:?}");

    // a second wave of pinned sessions, then an immediate drain: the
    // listener's final sweep must not strand them or hang the join
    let mut client = connect(&handle);
    let h = client.stream_open().unwrap();
    client.stream_append(&h, &words(&[4])).unwrap();
    drop(client);
    handle.stop();
    core.shutdown();
    assert_eq!(core.streams().active(), 0, "drain left a pinned lane behind");
}

/// The opt-in adaptive pacer, against a scripted server: soft-limit
/// advertisements grow the inter-request delay multiplicatively from
/// its base, a clear advertisement decays it, and the delay is capped.
#[test]
fn client_pacing_follows_soft_limit_advertisements() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // soft-limited twice, then clear, then soft-limited again (the
    // fourth response checks growth restarts from the decayed value,
    // not from the base)
    let script = [
        encode_backpressure(5, true),
        encode_backpressure(6, true),
        encode_backpressure(0, false),
        encode_backpressure(7, true),
    ];
    let server = std::thread::spawn(move || {
        let (s, _) = listener.accept().unwrap();
        let mut w = s.try_clone().unwrap();
        let mut r = FrameReader::new(s);
        let f = r.next_frame().unwrap().expect("expected a hello");
        assert_eq!(f.payload_type, PayloadType::Hello);
        Frame::new(PayloadType::HelloAck, 0, vec![PROTOCOL_VERSION, CAP_BACKPRESSURE])
            .write_to(&mut w)
            .unwrap();
        for flags in script {
            let f = r.next_frame().unwrap().expect("expected an infer request");
            assert_eq!(f.payload_type, PayloadType::InferRequest);
            let resp =
                WireResponse { pred: 1, v_out: 7, cycles: 10, latency_us: 5, batch: 1, worker: 0 };
            resp.frame(f.request_id).unwrap().with_flags(flags).write_to(&mut w).unwrap();
        }
    });

    let mut client = FrameClient::connect(addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(
        client.hello_with_caps(CAP_BACKPRESSURE).unwrap(),
        (PROTOCOL_VERSION, CAP_BACKPRESSURE)
    );
    let (base, max) = (Duration::from_millis(1), Duration::from_millis(4));
    client.enable_pacing(base, max);
    assert_eq!(client.pacing_delay(), Duration::ZERO, "no delay before any advertisement");

    let mut roundtrip = |want: Duration| {
        let p = client.call(&words(&[1])).unwrap();
        client.wait(&p).unwrap();
        assert_eq!(client.pacing_delay(), want);
    };
    roundtrip(base); // first soft-limit arms the base delay
    roundtrip(base * 2); // second doubles it
    roundtrip(base); // clear halves it
    roundtrip(base * 2); // growth resumes from the decayed value

    server.join().unwrap();
}
