//! Failure injection: corrupted artifacts, malformed inputs, and
//! out-of-envelope operation must fail loudly (errors), never silently
//! corrupt results.

use impulse::bitcell::Parity;
use impulse::data::binfmt::Tensor;
use impulse::data::SentimentArtifacts;
use impulse::energy::{ShmooModel, ShmooPath};
use impulse::isa::Instruction;
use impulse::macro_sim::{ImpulseMacro, MacroConfig};
use impulse::snn::{FcLayer, LayerParams, SentimentNetwork};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("impulse_failure_tests").join(name);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupted_tensor_file_is_rejected() {
    let d = tmpdir("corrupt");
    let p = d.join("t.bin");
    Tensor::from_i32(vec![4], &[1, 2, 3, 4]).write(&p).unwrap();
    // truncate mid-payload
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
    assert!(Tensor::read(&p).is_err());
    // flip the magic
    let mut bytes2 = bytes.clone();
    bytes2[0] ^= 0xFF;
    std::fs::write(&p, &bytes2).unwrap();
    assert!(Tensor::read(&p).is_err());
}

#[test]
fn missing_artifact_bundle_is_a_clean_error() {
    let d = tmpdir("empty_bundle");
    let err = SentimentArtifacts::load(&d).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unexpected error: {msg}");
}

#[test]
fn out_of_range_weights_rejected_by_validation() {
    let d = tmpdir("bad_weights");
    // minimal bundle with an out-of-range weight
    std::fs::write(
        d.join("manifest.txt"),
        "snn_thr_enc=50\nsnn_thr1=100\nsnn_thr2=100\n",
    )
    .unwrap();
    let s = d.join("sentiment");
    std::fs::create_dir_all(&s).unwrap();
    let w1: Vec<i32> = vec![40; 100 * 128]; // 40 > 31: not a 6-bit weight
    Tensor::from_i32(vec![100, 128], &w1).write(s.join("w1.bin")).unwrap();
    Tensor::from_i32(vec![128, 128], &[0; 128 * 128])
        .write(s.join("w2.bin"))
        .unwrap();
    Tensor::from_i32(vec![128, 1], &[0; 128]).write(s.join("w_out.bin")).unwrap();
    Tensor::from_i32(vec![2, 100], &[0; 200]).write(s.join("emb_q.bin")).unwrap();
    Tensor::from_i32(vec![1, 3], &[0, 1, -1]).write(s.join("test_seqs.bin")).unwrap();
    Tensor::from_i32(vec![1], &[2]).write(s.join("test_lens.bin")).unwrap();
    Tensor::from_i32(vec![1], &[1]).write(s.join("test_labels.bin")).unwrap();
    Tensor::from_i32(vec![0], &[]).write(s.join("polarity.bin")).unwrap();
    Tensor::from_i32(vec![1, 1], &[0]).write(s.join("ref_vout_traces.bin")).unwrap();
    Tensor::from_i32(vec![1], &[1]).write(s.join("ref_preds.bin")).unwrap();

    let a = SentimentArtifacts::load(&d).expect("bundle loads");
    assert!(a.validate().is_err(), "validation must reject 6-bit overflow");
    // and the network constructor (which validates) must refuse too
    assert!(SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).is_err());
}

#[test]
fn macro_rejects_malformed_instructions() {
    let mut m = ImpulseMacro::new(MacroConfig::fast());
    // out-of-range rows
    assert!(m
        .execute(&Instruction::AccW2V {
            w_row: 200,
            v_src: 0,
            v_dst: 0,
            parity: Parity::Odd
        })
        .is_err());
    assert!(m
        .execute(&Instruction::AccV2V {
            src_a: 0,
            src_b: 40,
            dst: 0,
            parity: Parity::Odd,
            mask: impulse::isa::WriteMaskMode::All
        })
        .is_err());
    // duplicate V reads (one wordline cannot fire twice)
    assert!(m
        .execute(&Instruction::SpikeCheck {
            v_row: 3,
            thr_row: 3,
            parity: Parity::Even
        })
        .is_err());
    // errors must not corrupt the cycle counter
    assert_eq!(m.cycles(), 0);
}

#[test]
#[should_panic(expected = "fan-in mismatch")]
fn layer_rejects_wrong_spike_width() {
    let w = vec![vec![1i64; 4]; 8];
    let mut layer = FcLayer::new(&w, LayerParams::rmp(10), MacroConfig::fast()).unwrap();
    let _ = layer.step(&[true; 9]); // 9 != 8
}

#[test]
fn fan_in_over_128_is_a_mapping_error() {
    let w = vec![vec![1i64; 4]; 129];
    let err = match FcLayer::new(&w, LayerParams::rmp(10), MacroConfig::fast()) {
        Err(e) => e,
        Ok(_) => panic!("mapping 129-input layer must fail"),
    };
    assert!(format!("{err}").contains("fan-in"), "{err}");
}

#[test]
fn operating_outside_shmoo_window_is_detectable() {
    // The coordinator checks the Shmoo model before accepting a
    // (V, f) configuration; points beyond the boundary must report
    // as failing.
    let shmoo = ShmooModel::calibrated();
    assert!(!shmoo.passes(ShmooPath::Cim, 0.85, 450.0e6));
    assert!(!shmoo.passes(ShmooPath::Cim, 0.60, 200.0e6));
    assert!(shmoo.passes(ShmooPath::Cim, 0.85, 200.0e6));
    // read/write window is wider but not unbounded
    assert!(!shmoo.passes(ShmooPath::ReadWrite, 0.60, 500.0e6));
}

#[test]
fn writev_out_of_range_value_panics() {
    let mut m = ImpulseMacro::new(MacroConfig::fast());
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = m.write_v(0, Parity::Odd, &[5000; 6]);
    }));
    assert!(result.is_err(), "writing a 13-bit value into V_MEM must assert");
}
