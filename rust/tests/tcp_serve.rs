//! End-to-end tests of the TCP serving front-end: the binary framed
//! transport and the stdio-path session must return bit-identical
//! predictions (the PR's acceptance criterion), concurrent clients
//! each get exactly one response per request id with no cross-talk,
//! and protocol violations are answered per the PROTOCOL.md contract.

// These tests deliberately drive the original per-workload client
// calls (`send_infer`/`next_result`, …): they pin the compatibility
// guarantee that pre-stream clients keep working unchanged.
#![allow(deprecated)]

use impulse::coordinator::{Response, ServerOptions};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::MacroConfig;
use impulse::serve::{
    decode_error, hello_payload, serve_tcp, ErrorCode, Frame, FrameClient, FrameReader,
    PayloadType, ServeCore, TcpServeHandle, WireResponse, PROTOCOL_VERSION,
};
use impulse::snn::{ReviewResult, SentimentNetwork};
use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const VOCAB: i64 = 20; // SentimentArtifacts::synthetic vocabulary

fn start_core(seed: u64, opts: ServerOptions) -> (Arc<ServeCore>, TcpServeHandle) {
    let a = SentimentArtifacts::synthetic(seed);
    assert_eq!(a.emb_q.len() as i64, VOCAB);
    let core = Arc::new(
        ServeCore::start_with(opts, VOCAB, move || {
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

/// Ground truth for one request: a solo network run with the serve
/// path's word-id clamping applied by hand.
fn solo(net: &mut SentimentNetwork, ids: &[i64]) -> ReviewResult {
    let clamped: Vec<i64> = ids.iter().map(|&w| w.clamp(0, VOCAB - 1)).collect();
    net.run_review(&clamped).unwrap()
}

/// The acceptance criterion: a request over TCP with the binary
/// framing returns a bit-identical prediction to the same request
/// over the stdio line-loop path (both against the solo ground
/// truth).
#[test]
fn tcp_binary_and_stdio_paths_are_bit_identical() {
    let seed = 71;
    let reqs: Vec<Vec<i64>> = vec![
        vec![3, 7, 5],
        vec![19],
        vec![0, 0, 0, 0, 0, 0, 0, 0],
        vec![999, -5, 7], // clamped into [0, 20) on every transport
        vec![2, 11, 6, 13, 4],
    ];
    let a = SentimentArtifacts::synthetic(seed);
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let want: Vec<ReviewResult> = reqs.iter().map(|r| solo(&mut net, r)).collect();

    let (core, handle) = start_core(
        seed,
        ServerOptions {
            workers: 2,
            batch_size: 4,
            batch_deadline: Duration::from_millis(5),
            ..ServerOptions::default()
        },
    );

    // --- binary TCP transport ---------------------------------------
    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
    for (i, r) in reqs.iter().enumerate() {
        client.send_infer(i as u64, r).unwrap();
    }
    let mut tcp: HashMap<u64, WireResponse> = HashMap::new();
    for _ in 0..reqs.len() {
        let (id, res) = client.next_result().unwrap().expect("stream ended early");
        let r = res.unwrap_or_else(|(c, m)| panic!("req {id} failed over TCP ({c}): {m}"));
        assert!(tcp.insert(id, r).is_none(), "req {id} answered twice");
    }
    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none(), "server must close after drain");

    // --- stdio-path session (what `impulse serve --stdio` drives) ---
    let session = core.client().unwrap();
    for (i, r) in reqs.iter().enumerate() {
        session.submit(i as u64, r).unwrap();
    }
    let mut line: HashMap<u64, Response> = HashMap::new();
    for _ in 0..reqs.len() {
        let r = session.recv().unwrap();
        assert!(line.insert(r.id, r).is_none());
    }
    drop(session);

    for (i, w) in want.iter().enumerate() {
        let t = &tcp[&(i as u64)];
        let l = &line[&(i as u64)];
        assert!(l.err.is_none(), "req {i} failed on stdio path: {:?}", l.err);
        assert_eq!((t.pred, t.v_out), (w.pred, w.v_out), "req {i}: TCP vs solo run");
        assert_eq!((l.pred, l.v_out), (w.pred, w.v_out), "req {i}: stdio vs solo run");
        assert!(t.cycles > 0 && l.cycles > 0, "req {i}: missing cost accounting");
    }
    handle.stop();
    core.shutdown();
}

/// Two concurrent clients — deliberately reusing the same request ids
/// — each get exactly one response per id, carrying their own
/// request's result (no cross-connection routing mistakes).
#[test]
fn two_clients_exactly_one_response_per_request_id() {
    let seed = 83;
    let n = 10u64;
    let words = |c: i64, i: i64| -> Vec<i64> { vec![(c * 7 + i * 3) % VOCAB, 5] };
    let a = SentimentArtifacts::synthetic(seed);
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let expected: Vec<Vec<ReviewResult>> = (0..2i64)
        .map(|c| (0..n as i64).map(|i| solo(&mut net, &words(c, i))).collect())
        .collect();
    let expected = Arc::new(expected);

    let (core, handle) = start_core(
        seed,
        ServerOptions {
            workers: 2,
            adaptive: true,
            ..ServerOptions::default()
        },
    );
    let addr = handle.local_addr();
    let clients: Vec<_> = (0..2i64)
        .map(|c| {
            let expected = Arc::clone(&expected);
            std::thread::spawn(move || {
                let mut client = FrameClient::connect(addr).unwrap();
                client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
                for i in 0..n {
                    client.send_infer(i, &words(c, i as i64)).unwrap();
                }
                let mut seen: HashMap<u64, WireResponse> = HashMap::new();
                for _ in 0..n {
                    let (id, res) =
                        client.next_result().unwrap().expect("stream ended early");
                    let r = res.unwrap_or_else(|e| panic!("client {c} req {id}: {e:?}"));
                    assert!(
                        seen.insert(id, r).is_none(),
                        "client {c}: req {id} answered twice"
                    );
                }
                for i in 0..n {
                    let want = &expected[c as usize][i as usize];
                    let got = &seen[&i];
                    assert_eq!(
                        (got.pred, got.v_out),
                        (want.pred, want.v_out),
                        "client {c} req {i}: cross-talk or wrong result"
                    );
                }
                client.finish_writes().unwrap();
                assert!(client.next_frame().unwrap().is_none());
            })
        })
        .collect();
    for h in clients {
        h.join().unwrap();
    }
    handle.stop();
    core.shutdown();
}

/// A stream that is not framed at all gets one Error frame (BadMagic)
/// and a close — alignment cannot be recovered.
#[test]
fn framing_error_is_answered_then_closed() {
    let (core, handle) = start_core(5, ServerOptions::default());
    let mut s = TcpStream::connect(handle.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    let mut reader = FrameReader::new(s.try_clone().unwrap());
    let f = reader.next_frame().unwrap().expect("expected an error frame");
    assert_eq!(f.payload_type, PayloadType::Error);
    assert_eq!(f.request_id, 0, "no request id is attributable to a framing error");
    let (code, _) = decode_error(&f.payload).unwrap();
    assert_eq!(code, ErrorCode::BadMagic.as_u16());
    assert!(reader.next_frame().unwrap().is_none(), "connection must close");
    handle.stop();
    core.shutdown();
}

/// An empty request is answered with EmptyRequest and the connection
/// stays usable (the stream is still frame-aligned).
#[test]
fn empty_request_errors_but_connection_survives() {
    let (core, handle) = start_core(9, ServerOptions::default());
    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    client.send_infer(1, &[]).unwrap();
    let (id, res) = client.next_result().unwrap().unwrap();
    assert_eq!(id, 1);
    assert_eq!(res.unwrap_err().0, ErrorCode::EmptyRequest.as_u16());
    client.send_infer(2, &[3, 4]).unwrap();
    let (id, res) = client.next_result().unwrap().unwrap();
    assert_eq!(id, 2);
    assert!(res.is_ok(), "stream must still be aligned after a request error");
    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none());
    handle.stop();
    core.shutdown();
}

/// Version negotiation: an incompatible Hello is refused with
/// UnsupportedVersion and the connection closes.
#[test]
fn unsupported_version_is_refused() {
    let (core, handle) = start_core(3, ServerOptions::default());
    let mut s = TcpStream::connect(handle.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    Frame::new(PayloadType::Hello, 0, hello_payload(2, 9)).write_to(&mut s).unwrap();
    let mut reader = FrameReader::new(s.try_clone().unwrap());
    let f = reader.next_frame().unwrap().expect("expected an error frame");
    assert_eq!(f.payload_type, PayloadType::Error);
    let (code, _) = decode_error(&f.payload).unwrap();
    assert_eq!(code, ErrorCode::UnsupportedVersion.as_u16());
    assert!(reader.next_frame().unwrap().is_none(), "connection must close");
    handle.stop();
    core.shutdown();
}

/// Digits over the framed transport: a fleet of pipelined image
/// requests served with adaptive batching (fused conv lanes) must be
/// bit-identical to solo `run_image` runs — batched-vs-sequential
/// parity through the TCP serve path.
#[test]
fn digits_requests_over_tcp_match_solo_runs() {
    use impulse::data::DigitsArtifacts;
    use impulse::snn::DigitsNetwork;

    let seed = 47;
    let a = DigitsArtifacts::synthetic(seed);
    let mut solo = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let n = 4usize;
    let want: Vec<_> = a.test_x[..n]
        .iter()
        .map(|img| solo.run_image(img).unwrap())
        .collect();

    let a2 = a.clone();
    let core = Arc::new(
        ServeCore::start_with(
            ServerOptions {
                workers: 2,
                adaptive: true,
                ..ServerOptions::default()
            },
            1,
            move || DigitsNetwork::from_artifacts(&a2, MacroConfig::fast()),
        )
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();

    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
    for (i, img) in a.test_x[..n].iter().enumerate() {
        client.send_digits_infer(i as u64, 28, 28, img).unwrap();
    }
    let mut seen = HashMap::new();
    for _ in 0..n {
        let (id, res) = client.next_digits_result().unwrap().expect("stream ended early");
        let r = res.unwrap_or_else(|(c, m)| panic!("req {id} failed over TCP ({c}): {m}"));
        assert!(seen.insert(id, r).is_none(), "req {id} answered twice");
    }
    for (i, w) in want.iter().enumerate() {
        let got = &seen[&(i as u64)];
        assert_eq!(got.pred, w.pred, "req {i}: TCP vs solo prediction");
        assert_eq!(got.v_all, w.v_out, "req {i}: TCP vs solo potentials");
        assert!(got.cycles > 0, "req {i}: missing cost accounting");
    }
    // a malformed digits payload (wrong shape for the workload) errors
    // per request and the connection stays usable
    client.send_digits_infer(99, 2, 2, &[0.0; 4]).unwrap();
    let (id, res) = client.next_digits_result().unwrap().unwrap();
    assert_eq!(id, 99);
    assert_eq!(res.unwrap_err().0, ErrorCode::InferenceFailed.as_u16());
    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none(), "server must close after drain");
    handle.stop();
    core.shutdown();
}

/// A `DigitsInferRequest` on a *sentiment* server is answered with an
/// InferenceFailed error frame (the workload seam), not a hang or a
/// misparse.
#[test]
fn digits_payload_on_sentiment_server_errors_cleanly() {
    let (core, handle) = start_core(13, ServerOptions::default());
    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    client.send_digits_infer(5, 28, 28, &[0.0; 28 * 28]).unwrap();
    let (id, res) = client.next_digits_result().unwrap().unwrap();
    assert_eq!(id, 5);
    assert_eq!(res.unwrap_err().0, ErrorCode::InferenceFailed.as_u16());
    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none());
    handle.stop();
    core.shutdown();
}
