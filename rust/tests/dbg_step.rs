//! One-step debug probe of the XLA runtime vs the artifact bundle.
//! Skips (with a notice) when `make artifacts` has not run or the
//! crate was built without the `xla` feature.

use impulse::data::{artifacts_available, artifacts_dir, SentimentArtifacts};
use impulse::runtime::{xla_available, SentimentStepRuntime, StepState};

#[test]
fn dbg_one_step() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    if !xla_available() {
        eprintln!("SKIP: built without the `xla` feature");
        return;
    }
    let dir = artifacts_dir();
    let a = SentimentArtifacts::load(&dir).unwrap();
    let rt = SentimentStepRuntime::load(&dir, 100, 128, 128).unwrap();
    let wid = a.test_seqs[0][0] as usize;
    let x: Vec<i32> = a.emb_q[wid].iter().map(|&v| v as i32).collect();
    let mut st = StepState::zeros(100, 128, 128);
    rt.step(&x, &mut st).unwrap();
    println!("x[0..6]={:?}", &x[..6]);
    println!("v_e[0..6]={:?}", &st.v_e[..6]);
    println!("v1[0..6]={:?}", &st.v1[..6]);
    println!("v_o={}", st.v_o);
    println!("thr_enc={} thr1={} thr2={}", a.thr_enc, a.thr1, a.thr2);
}
