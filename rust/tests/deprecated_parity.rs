//! The deprecated per-workload client surface (`send_infer` /
//! `next_result` / `send_digits_infer` / `send_stats`) is frozen, not
//! abandoned: this test pins that it produces BYTE-IDENTICAL wire
//! traffic to the typed `call`/`wait` surface, and identical results
//! against a live server — so pre-stream clients built on the old
//! calls keep interoperating with servers exercised only through the
//! typed path.

// Exercising the deprecated surface is this test's entire point.
#![allow(deprecated)]

use impulse::coordinator::{ServerOptions, WorkloadInput};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::MacroConfig;
use impulse::serve::{
    encode_stats_request, serve_tcp, ErrorCode, Frame, FrameClient, PayloadType, ServeCore,
    ServerError, PROTOCOL_VERSION,
};
use impulse::snn::SentimentNetwork;
use std::io::Read;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

const WORDS: [i64; 4] = [3, 1, 4, 15];
const IMAGE: [f32; 4] = [0.0, 0.5, 1.0, -1.0];

/// Accept one connection and read exactly `n` bytes off it.
fn read_n(listener: &TcpListener, n: usize) -> Vec<u8> {
    let (mut s, _) = listener.accept().unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).unwrap();
    buf
}

/// Wire-level identity: the bytes `call` puts on the socket for a
/// words and an image request are exactly the bytes `send_infer` /
/// `send_digits_infer` put there for the same request ids (the typed
/// surface auto-assigns ids from 1).
#[test]
fn typed_call_and_deprecated_sends_are_byte_identical() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // compute the expected sizes from the documented encoding
    let want_words = Frame::new(
        PayloadType::InferRequest,
        1,
        impulse::serve::encode_infer_request(&WORDS).unwrap(),
    )
    .encode();
    let want_image = Frame::new(
        PayloadType::DigitsInferRequest,
        2,
        impulse::serve::encode_digits_request(2, 2, &IMAGE).unwrap(),
    )
    .encode();
    let total = want_words.len() + want_image.len();

    // typed surface: ids 1 and 2 are auto-assigned
    let mut typed = FrameClient::connect(addr).unwrap();
    typed.call(&WorkloadInput::Words(WORDS.to_vec())).unwrap();
    typed
        .call(&WorkloadInput::Image { h: 2, w: 2, pixels: IMAGE.to_vec() })
        .unwrap();
    let typed_bytes = read_n(&listener, total);
    drop(typed);

    // deprecated surface: the same ids passed explicitly
    let mut old = FrameClient::connect(addr).unwrap();
    old.send_infer(1, &WORDS).unwrap();
    old.send_digits_infer(2, 2, 2, &IMAGE).unwrap();
    let old_bytes = read_n(&listener, total);
    drop(old);

    assert_eq!(typed_bytes, old_bytes, "typed and deprecated sends differ on the wire");
    // and both are the documented encoding, not merely equal mistakes
    assert_eq!(typed_bytes[..want_words.len()], want_words[..]);
    assert_eq!(typed_bytes[want_words.len()..], want_image[..]);
}

/// `send_stats` writes exactly the frame the typed `stats` call
/// writes for the same request id.
#[test]
fn deprecated_send_stats_matches_documented_encoding() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let want = Frame::new(PayloadType::StatsRequest, 5, encode_stats_request()).encode();

    let mut old = FrameClient::connect(addr).unwrap();
    old.send_stats(5).unwrap();
    let got = read_n(&listener, want.len());
    drop(old);
    assert_eq!(got, want);
}

fn start_server() -> (Arc<ServeCore>, impulse::serve::TcpServeHandle) {
    let a = SentimentArtifacts::synthetic(53);
    let vocab = a.emb_q.len() as i64;
    let core = Arc::new(
        ServeCore::start_with(ServerOptions::default(), vocab, move || {
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

/// Behavioral identity against a live server: the deprecated
/// send/next pair and the typed call/wait pair return the same
/// prediction, potential, and cycle count for the same request — and
/// the same error code for a request the workload rejects.
#[test]
fn deprecated_and_typed_results_agree_on_a_live_server() {
    let (core, handle) = start_server();
    let addr = handle.local_addr();

    // typed surface
    let mut typed = FrameClient::connect(addr).unwrap();
    typed.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(typed.hello().unwrap(), PROTOCOL_VERSION);
    let p = typed.call(&WorkloadInput::Words(WORDS.to_vec())).unwrap();
    let out = typed.wait(&p).unwrap();
    let p = typed
        .call(&WorkloadInput::Image { h: 28, w: 28, pixels: vec![0.0; 784] })
        .unwrap();
    let typed_err = typed.wait(&p).unwrap_err();
    let typed_code = typed_err
        .downcast_ref::<ServerError>()
        .expect("typed rejection carries a ServerError")
        .code;
    drop(typed);

    // deprecated surface, fresh connection, same requests
    let mut old = FrameClient::connect(addr).unwrap();
    old.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(old.hello().unwrap(), PROTOCOL_VERSION);
    old.send_infer(1, &WORDS).unwrap();
    let (id, res) = old.next_result().unwrap().expect("stream ended early");
    assert_eq!(id, 1);
    let r = res.expect("infer must succeed on the deprecated surface");
    assert_eq!(
        (r.pred, r.v_out, r.cycles),
        (out.pred, out.v_out, out.cycles),
        "deprecated and typed surfaces disagree on the same request"
    );
    old.send_digits_infer(2, 28, 28, &[0.0; 784]).unwrap();
    let (id, res) = old.next_digits_result().unwrap().expect("stream ended early");
    assert_eq!(id, 2);
    let (code, _) = res.expect_err("sentiment server must reject an image");
    assert_eq!(code, typed_code, "rejection code differs between surfaces");
    assert_eq!(code, ErrorCode::InferenceFailed.as_u16());
    old.finish_writes().unwrap();
    assert!(old.next_frame().unwrap().is_none());

    handle.stop();
    core.shutdown();
}
