//! Property tests for the static program analyzer (`isa::verify`).
//!
//! Two families:
//!
//! - **Clean streams stay clean**: every built-in stream — the Fig 6
//!   neuron sequences and the schedule builders of both model networks
//!   — validates with zero diagnostics under the strict validator.
//! - **Mutations are caught**: seeded single-instruction mutations of a
//!   known-clean schedule (row knocked out of range, parity flipped,
//!   spike-gate order swapped) each produce the documented rule code.

use impulse::bitcell::Parity;
use impulse::bits::XorShiftRng;
use impulse::data::{DigitsArtifacts, SentimentArtifacts};
use impulse::isa::verify::{check_fused_stream, RuleCode};
use impulse::isa::{neuron_sequence, Instruction, NeuronType, Program, ProgramValidator};
use impulse::macro_sim::MacroConfig;
use impulse::mapper::ConstRows;
use impulse::snn::{DigitsNetwork, FcLayer, LayerParams, SentimentNetwork};

fn strict() -> ProgramValidator {
    ProgramValidator::new()
}

fn fragment() -> ProgramValidator {
    ProgramValidator::new().assume_initialized(true)
}

/// A small known-clean LIF layer whose schedule exercises every
/// instruction kind and all three constant rows each timestep.
fn lif_fixture() -> FcLayer {
    let weights: Vec<Vec<i64>> = (0..8).map(|i| vec![(i % 5) - 2; 4]).collect();
    FcLayer::new(&weights, LayerParams::lif(20, 1), MacroConfig::fast()).unwrap()
}

fn instrs_of(p: &Program) -> Vec<Instruction> {
    p.iter().copied().collect()
}

fn other(p: Parity) -> Parity {
    match p {
        Parity::Odd => Parity::Even,
        Parity::Even => Parity::Odd,
    }
}

// ---------------------------------------------------------------- clean

#[test]
fn neuron_sequences_validate_clean() {
    let cr = ConstRows::default();
    for parity in Parity::BOTH {
        for (ty, v_row) in [
            (NeuronType::IF, 0),
            (NeuronType::LIF, 2),
            (NeuronType::RMP, 4),
        ] {
            let v_row = match parity {
                Parity::Odd => v_row,
                Parity::Even => v_row + 1,
            };
            let seq = neuron_sequence(ty, v_row, cr.for_parity(parity), parity);
            let report = fragment().validate_instrs(&seq);
            assert!(report.is_clean(), "{ty:?}/{parity:?}: {report}");
        }
    }
}

#[test]
fn fc_schedules_validate_clean_for_every_neuron_type() {
    let weights: Vec<Vec<i64>> = (0..6).map(|_| vec![1; 3]).collect();
    for params in [
        LayerParams::if_(10),
        LayerParams::lif(10, 1),
        LayerParams::rmp(10),
    ] {
        let layer = FcLayer::new(&weights, params, MacroConfig::fast()).unwrap();
        let report = strict().validate(&layer.schedule_program(3));
        assert!(report.is_clean(), "{:?}: {report}", params.neuron);
    }
    // output-only layers skip the neuron sequence but still read out
    let out = FcLayer::new(&weights, LayerParams::rmp(10), MacroConfig::fast())
        .unwrap()
        .output_only();
    let report = strict().validate(&out.schedule_program(3));
    assert!(report.is_clean(), "output_only: {report}");
}

#[test]
fn sentiment_schedules_validate_clean() {
    let a = SentimentArtifacts::synthetic(7);
    let net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let programs = net.schedule_programs(3);
    assert_eq!(programs.len(), 3);
    for (label, prog) in programs {
        let report = strict().validate(&prog);
        assert!(report.is_clean(), "sentiment/{label}: {report}");
        assert!(!prog.is_empty(), "sentiment/{label}: empty schedule");
    }
}

#[test]
fn digits_schedules_validate_clean() {
    let a = DigitsArtifacts::synthetic(7);
    let net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
    let programs = net.schedule_programs(2);
    assert_eq!(programs.len(), 4);
    for (label, prog) in programs {
        let report = strict().validate(&prog);
        assert!(report.is_clean(), "digits/{label}: {report}");
        assert!(!prog.is_empty(), "digits/{label}: empty schedule");
    }
}

// ------------------------------------------------------------ mutations

/// Knock one row operand out of range; returns the rule that must fire.
fn bump_row(instr: &mut Instruction) -> RuleCode {
    match instr {
        Instruction::AccW2V { w_row, .. } | Instruction::WriteW { w_row, .. } => {
            *w_row = 128;
            RuleCode::WRowRange
        }
        Instruction::AccV2V { dst, .. } | Instruction::ResetV { dst, .. } => {
            *dst = 32;
            RuleCode::VRowRange
        }
        Instruction::SpikeCheck { v_row, .. }
        | Instruction::ReadV { v_row, .. }
        | Instruction::WriteV { v_row, .. } => {
            *v_row = 32;
            RuleCode::VRowRange
        }
    }
}

#[test]
fn mutated_row_out_of_range_is_caught() {
    let base = instrs_of(&lif_fixture().schedule_program(2));
    let mut rng = XorShiftRng::new(0x5eed_0001);
    for _ in 0..32 {
        let mut instrs = base.clone();
        let ix = rng.gen_range(instrs.len() as u64) as usize;
        let expected = bump_row(&mut instrs[ix]);
        let report = strict().validate_instrs(&instrs);
        assert!(report.has(expected), "mutation at #{ix}: {report}");
        assert!(!report.passes(), "mutation at #{ix} must be an error");
    }
}

/// Flip the parity of a V-touching instruction (WriteW has none).
fn flip_parity(instr: &mut Instruction) -> bool {
    match instr {
        Instruction::AccW2V { parity, .. }
        | Instruction::AccV2V { parity, .. }
        | Instruction::SpikeCheck { parity, .. }
        | Instruction::ResetV { parity, .. }
        | Instruction::ReadV { parity, .. }
        | Instruction::WriteV { parity, .. } => {
            *parity = other(*parity);
            true
        }
        Instruction::WriteW { .. } => false,
    }
}

#[test]
fn mutated_parity_flip_is_caught() {
    // In a LIF schedule every V row an instruction touches (membranes
    // and all three constants) is touched again under the same parity,
    // so flipping any single instruction's parity must conflict.
    let base = instrs_of(&lif_fixture().schedule_program(2));
    let mut rng = XorShiftRng::new(0x5eed_0002);
    let mut applied = 0;
    while applied < 32 {
        let mut instrs = base.clone();
        let ix = rng.gen_range(instrs.len() as u64) as usize;
        if !flip_parity(&mut instrs[ix]) {
            continue;
        }
        applied += 1;
        let report = strict().validate_instrs(&instrs);
        assert!(
            report.has(RuleCode::ParityConflict),
            "parity flip at #{ix}: {report}"
        );
        assert!(!report.passes(), "parity flip at #{ix} must be an error");
    }
}

#[test]
fn swapped_gate_order_is_caught() {
    // Move the SpikeCheck after its gated partner: the gated op then
    // issues against a never-latched spike buffer.
    let cr = ConstRows::default();
    let mut rng = XorShiftRng::new(0x5eed_0003);
    for _ in 0..16 {
        let parity = if rng.gen_bool(0.5) { Parity::Odd } else { Parity::Even };
        let ty = match rng.gen_range(3) {
            0 => NeuronType::IF,
            1 => NeuronType::LIF,
            _ => NeuronType::RMP,
        };
        let v_row = match parity {
            Parity::Odd => 0,
            Parity::Even => 1,
        };
        let mut seq = neuron_sequence(ty, v_row, cr.for_parity(parity), parity);
        let check_ix = seq
            .iter()
            .position(|i| matches!(i, Instruction::SpikeCheck { .. }))
            .expect("every sequence latches the spike buffer");
        assert!(check_ix + 1 < seq.len(), "SpikeCheck must gate a successor");
        seq.swap(check_ix, check_ix + 1);
        let report = fragment().validate_instrs(&seq);
        assert!(
            report.has(RuleCode::GateNeverLatched),
            "{ty:?}/{parity:?}: {report}"
        );
        assert!(!report.passes(), "{ty:?}/{parity:?} must be an error");
    }
}

// ----------------------------------------------------- targeted hazards

#[test]
fn stale_gate_is_flagged() {
    let instrs = [
        Instruction::SpikeCheck {
            v_row: 0,
            thr_row: 28,
            parity: Parity::Odd,
        },
        // rewriting the checked row invalidates the latched comparison
        Instruction::AccV2V {
            src_a: 0,
            src_b: 26,
            dst: 0,
            parity: Parity::Odd,
            mask: impulse::isa::WriteMaskMode::All,
        },
        Instruction::ResetV {
            reset_row: 30,
            dst: 0,
            parity: Parity::Odd,
        },
    ];
    let report = fragment().validate_instrs(&instrs);
    assert!(report.has(RuleCode::GateStale), "{report}");
    assert!(report.passes(), "stale gate is a warning: {report}");
    assert!(!report.is_clean());
}

#[test]
fn dead_store_is_flagged_at_the_overwritten_index() {
    let instrs = [
        Instruction::WriteV {
            v_row: 3,
            parity: Parity::Odd,
            values: [1; 6],
        },
        Instruction::WriteV {
            v_row: 3,
            parity: Parity::Odd,
            values: [2; 6],
        },
        Instruction::ReadV {
            v_row: 3,
            parity: Parity::Odd,
        },
    ];
    let report = strict().validate_instrs(&instrs);
    let dead: Vec<_> = report
        .diagnostics()
        .iter()
        .filter(|d| d.code == RuleCode::DeadStore)
        .collect();
    assert_eq!(dead.len(), 1, "{report}");
    assert_eq!(dead[0].index, Some(0), "{report}");
    assert!(report.passes());
}

#[test]
fn use_before_init_only_in_strict_mode() {
    let instrs = [Instruction::ReadV {
        v_row: 4,
        parity: Parity::Odd,
    }];
    let report = strict().validate_instrs(&instrs);
    assert!(report.has(RuleCode::UseBeforeInit), "{report}");
    assert!(report.passes(), "use-before-init is a warning");
    assert!(fragment().validate_instrs(&instrs).is_clean());
}

#[test]
fn const_row_clobber_is_an_error() {
    let instrs = [
        Instruction::WriteV {
            v_row: 5,
            parity: Parity::Odd,
            values: [1; 6],
        },
        // a CIM write landing on the row later used as −θ
        Instruction::AccW2V {
            w_row: 0,
            v_src: 5,
            v_dst: 28,
            parity: Parity::Odd,
        },
        Instruction::SpikeCheck {
            v_row: 5,
            thr_row: 28,
            parity: Parity::Odd,
        },
    ];
    let report = strict().validate_instrs(&instrs);
    assert!(report.has(RuleCode::ConstClobber), "{report}");
    assert!(!report.passes());
}

// ------------------------------------------------- fused-stream contract

#[test]
fn fused_stream_preconditions_each_have_a_code() {
    let too_many: Vec<usize> = (0..33).collect();
    let cases: Vec<(Vec<(usize, u32)>, Vec<usize>, RuleCode)> = vec![
        (vec![], too_many, RuleCode::FusedLaneCount),
        (vec![], vec![32], RuleCode::VRowRange),
        (vec![], vec![0, 2, 0], RuleCode::FusedLaneDup),
        (vec![(128, 1)], vec![0], RuleCode::WRowRange),
        (vec![(0, 0b100)], vec![0, 2], RuleCode::FusedMaskWidth),
        (vec![(9, 1), (4, 1)], vec![0], RuleCode::FusedRowOrder),
        (vec![(4, 1), (4, 1)], vec![0], RuleCode::FusedRowOrder),
    ];
    for (rows, lanes, expected) in cases {
        let err = check_fused_stream(&rows, &lanes)
            .expect_err(&format!("{rows:?}/{lanes:?} must be rejected"));
        assert_eq!(err.code, expected, "{rows:?}/{lanes:?}: {err}");
    }
    // the canonical sorted-unique shape passes
    check_fused_stream(&[(0, 0b11), (5, 0b01), (90, 0b10)], &[0, 2]).unwrap();
}

// ----------------------------------------------------------- rendering

#[test]
fn json_report_carries_stable_codes() {
    let instrs = [Instruction::ReadV {
        v_row: 32,
        parity: Parity::Odd,
    }];
    let json = strict().validate_instrs(&instrs).to_json();
    assert!(json.contains("\"errors\":1"), "{json}");
    assert!(json.contains("\"code\":\"S002\""), "{json}");
    assert!(json.contains("\"rule\":\"v-row-range\""), "{json}");
    assert!(json.contains("\"index\":0"), "{json}");
}
