//! End-to-end tests of the fault-tolerant proxy tier: route-through
//! parity with a direct connection (bit-identical outputs, flags and
//! trace-echo trailers passing through), zero lost idempotent
//! one-shots when a backend is killed mid-burst, stream pinning
//! across a balanced fleet, honest `BackendLost` answers (never a
//! hang) when a pinned backend dies, and soft-limit spill routing.

use impulse::coordinator::{ServerOptions, WorkloadInput};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::MacroConfig;
use impulse::obs::trace::TraceRecorder;
use impulse::proxy::{
    serve_proxy, FaultRelay, ProxyCore, ProxyOptions, ProxyServeHandle,
};
use impulse::serve::{
    decode_backpressure, serve_tcp, ErrorCode, FrameClient, ServeCore, ServerError,
    TcpServeHandle, CAP_BACKPRESSURE, CAP_TRACE_ECHO, PROTOCOL_VERSION,
};
use impulse::snn::SentimentNetwork;
use impulse::telemetry::{Telemetry, TelemetryConfig};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

const VOCAB: i64 = 20; // SentimentArtifacts::synthetic vocabulary

fn start_backend(seed: u64, opts: ServerOptions) -> (Arc<ServeCore>, TcpServeHandle) {
    let a = SentimentArtifacts::synthetic(seed);
    let core = Arc::new(
        ServeCore::start_with(opts, VOCAB, move || {
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

/// Proxy options tightened for tests: fast health rounds and
/// reconnect attempts so failure detection fits a test budget.
fn start_proxy(backends: Vec<String>) -> (Arc<ProxyCore>, ProxyServeHandle) {
    let mut opts = ProxyOptions::new(backends);
    opts.health_interval = Duration::from_millis(100);
    opts.health_timeout = Duration::from_millis(750);
    opts.reconnect_base = Duration::from_millis(50);
    let core = ProxyCore::start(opts).unwrap();
    let handle = serve_proxy("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

/// Block until the proxy reports `n` backends `Up` (links connect
/// asynchronously after [`ProxyCore::start`]).
fn wait_up(core: &ProxyCore, n: usize) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while core.up_backends() < n {
        assert!(
            Instant::now() < deadline,
            "only {}/{n} backends came up within 10s",
            core.up_backends()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn connect(addr: SocketAddr) -> FrameClient {
    let mut c = FrameClient::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c.hello().unwrap();
    c
}

/// A deterministic sentiment request derived from `i`.
fn words(i: i64) -> WorkloadInput {
    WorkloadInput::Words(vec![(i * 7 + 3) % VOCAB, (i * 5 + 1) % VOCAB, i % VOCAB])
}

/// Route-through parity: the same requests through the proxy and
/// straight at the backend produce bit-identical outputs, the
/// backend's backpressure advertisement survives the hop, and the
/// trace-echo trailer a tracing backend attaches reaches the client.
#[test]
fn proxied_requests_are_bit_identical_and_flags_flow_through() {
    let seed = 71;
    let trace = Arc::new(TraceRecorder::new());
    let (bcore, bhandle) = start_backend(
        seed,
        ServerOptions { trace: Some(Arc::clone(&trace)), ..ServerOptions::default() },
    );
    let (pcore, phandle) = start_proxy(vec![bhandle.local_addr().to_string()]);
    wait_up(&pcore, 1);

    let mut direct = connect(bhandle.local_addr());
    let mut proxied = FrameClient::connect(phandle.local_addr()).unwrap();
    proxied.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    // the proxy negotiates hello locally, granting the full cap set
    let (ver, caps) = proxied.hello_with_caps(CAP_BACKPRESSURE | CAP_TRACE_ECHO).unwrap();
    assert_eq!(ver, PROTOCOL_VERSION);
    assert_eq!(caps, CAP_BACKPRESSURE | CAP_TRACE_ECHO);

    for i in 0..8 {
        let input = words(i);
        let d = direct.call(&input).and_then(|p| direct.wait(&p)).unwrap();
        let x = proxied.call(&input).and_then(|p| proxied.wait(&p)).unwrap();
        assert_eq!(
            (d.pred, d.v_out, &d.v_all, d.cycles),
            (x.pred, x.v_out, &x.v_all, x.cycles),
            "request {i}: proxied result differs from direct"
        );
    }

    // the backend's backpressure advertisement is relayed verbatim
    let (snap, flags) = proxied.stats().unwrap();
    assert!(
        decode_backpressure(flags).is_some(),
        "stats flags {flags:#06x} lost the backpressure advertisement at the proxy hop"
    );
    assert!(snap.kinds.iter().map(|k| k.submitted).sum::<u64>() >= 16);

    // the trace-echo trailer flows through too (the backend traces)
    proxied.set_trace_echo(true);
    let p = proxied.call(&words(3)).unwrap();
    let (_, echo) = proxied.wait_with_trace(&p).unwrap();
    assert!(echo.is_some(), "trace-echo trailer dropped at the proxy hop");

    phandle.stop();
    pcore.shutdown();
    bhandle.stop();
    bcore.shutdown();
}

/// The acceptance criterion: kill one of two backends mid-burst and
/// every idempotent one-shot still gets its answer — in-flight work
/// on the dead backend is transparently re-submitted to the survivor.
#[test]
fn backend_kill_mid_burst_loses_no_idempotent_one_shots() {
    let seed = 83;
    let (a_core, a_handle) = start_backend(seed, ServerOptions::default());
    let (b_core, b_handle) = start_backend(seed, ServerOptions::default());
    // backend B sits behind the fault relay so it can be "kill -9"ed
    let relay = FaultRelay::start(&b_handle.local_addr().to_string()).unwrap();
    let (pcore, phandle) = start_proxy(vec![
        a_handle.local_addr().to_string(),
        relay.local_addr().to_string(),
    ]);
    wait_up(&pcore, 2);

    let mut client = connect(phandle.local_addr());
    let n = 40;
    let mut pendings = Vec::with_capacity(n);
    for i in 0..n {
        pendings.push(client.call(&words(i as i64)).unwrap());
        if i == n / 2 {
            // connections reset, port stops answering — mid-burst
            relay.kill();
        }
    }
    for (i, p) in pendings.iter().enumerate() {
        let out = client
            .wait_timeout(p, Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("request {i} lost in the failover: {e:#}"));
        assert!(out.cycles > 0, "request {i}: missing cost accounting");
    }

    let snap = pcore.stats().snapshot();
    assert!(snap[1].failovers >= 1, "the dead backend's failover was never recorded: {snap:?}");
    assert!(snap[0].requests > 0, "the survivor served nothing: {snap:?}");

    phandle.stop();
    pcore.shutdown();
    relay.stop();
    a_handle.stop();
    a_core.shutdown();
    b_handle.stop();
    b_core.shutdown();
}

/// Streams pin to one backend for their whole life: interleaved with
/// load-balanced one-shots (which spread over both backends), every
/// append/read-out/close reaches the backend holding that stream's
/// membrane state. A closed stream answers `StreamExpired`, proving
/// the pin was released.
#[test]
fn streams_stay_pinned_across_a_balanced_fleet() {
    let seed = 91;
    let (a_core, a_handle) = start_backend(seed, ServerOptions::default());
    let (b_core, b_handle) = start_backend(seed, ServerOptions::default());
    let (pcore, phandle) = start_proxy(vec![
        a_handle.local_addr().to_string(),
        b_handle.local_addr().to_string(),
    ]);
    wait_up(&pcore, 2);

    let mut client = connect(phandle.local_addr());
    // keep one-shots in flight while opening, so the least-loaded
    // picks spread opens (and traffic) over both backends
    let mut pendings = Vec::new();
    let mut handles = Vec::new();
    for i in 0..4i64 {
        pendings.push(client.call(&words(i)).unwrap());
        handles.push(client.stream_open().unwrap());
    }
    for p in &pendings {
        client.wait(p).unwrap();
    }

    for round in 0..5i64 {
        for (i, h) in handles.iter().enumerate() {
            let ack = client
                .stream_append(h, &words(round * 4 + i as i64))
                .unwrap_or_else(|e| panic!("round {round} stream {i}: append mis-routed: {e:#}"));
            assert_eq!(ack.stream_id, h.id(), "ack for the wrong stream");
        }
    }
    for (i, h) in handles.iter().enumerate() {
        let out = client.stream_read_out(h).unwrap();
        assert_eq!(out.v_all.len(), 1, "stream {i}: sentiment read-out shape");
        let ack = client.stream_close(h).unwrap();
        assert!(ack.cycles > 0, "stream {i}: missing cumulative cycles");
    }

    // both backends took part — the pins were genuinely spread
    let snap = pcore.stats().snapshot();
    assert!(
        snap.iter().all(|b| b.requests > 0),
        "traffic never spread over the fleet: {snap:?}"
    );

    // a closed stream's pin is gone: the proxy answers StreamExpired
    // itself (same contract a backend honors for unknown streams)
    let err = client.stream_append(&handles[0], &words(1)).unwrap_err();
    let se = err.downcast_ref::<ServerError>().expect("an error frame, not a transport failure");
    assert_eq!(se.error_code(), Some(ErrorCode::StreamExpired), "{se}");

    phandle.stop();
    pcore.shutdown();
    a_handle.stop();
    a_core.shutdown();
    b_handle.stop();
    b_core.shutdown();
}

/// When the backend holding a pinned stream dies, later operations on
/// that stream answer `BackendLost` — an honest error, never a hang —
/// and one-shots with no backend left get the same honest refusal.
#[test]
fn pinned_stream_death_answers_backend_lost_not_a_hang() {
    let seed = 77;
    let (b_core, b_handle) = start_backend(seed, ServerOptions::default());
    let relay = FaultRelay::start(&b_handle.local_addr().to_string()).unwrap();
    let (pcore, phandle) = start_proxy(vec![relay.local_addr().to_string()]);
    wait_up(&pcore, 1);

    let mut client = connect(phandle.local_addr());
    let h = client.stream_open().unwrap();
    client.stream_append(&h, &words(1)).unwrap();

    relay.kill();
    // the failover must record the pinned stream's loss
    let deadline = Instant::now() + Duration::from_secs(10);
    while pcore.stats().snapshot()[0].streams_lost == 0 {
        assert!(Instant::now() < deadline, "stream loss never recorded after the kill");
        std::thread::sleep(Duration::from_millis(10));
    }

    let err = client.stream_append(&h, &words(2)).unwrap_err();
    let se = err.downcast_ref::<ServerError>().expect("an error frame, not a transport failure");
    assert_eq!(se.error_code(), Some(ErrorCode::BackendLost), "{se}");

    // a one-shot with every backend down is refused the same way
    let p = client.call(&words(3)).unwrap();
    let err = client.wait_timeout(&p, Duration::from_secs(10)).unwrap_err();
    let se = err.downcast_ref::<ServerError>().expect("an error frame, not a timeout");
    assert_eq!(se.error_code(), Some(ErrorCode::BackendLost), "{se}");

    let snap = pcore.stats().snapshot();
    assert!(snap[0].failovers >= 1 && snap[0].streams_lost >= 1, "{snap:?}");

    phandle.stop();
    pcore.shutdown();
    relay.stop();
    b_handle.stop();
    b_core.shutdown();
}

/// A backend advertising the soft limit sheds new one-shots to its
/// unconstrained peer, and the diversion is counted as a spill.
#[test]
fn soft_limited_backend_spills_new_work_to_its_peer() {
    let seed = 67;
    // backend A advertises the soft limit on every response (limit 0
    // = always, the drain convention)
    let tel = Arc::new(Telemetry::new(TelemetryConfig {
        queue_soft_limit: 0,
        ..TelemetryConfig::default()
    }));
    let (a_core, a_handle) = start_backend(
        seed,
        ServerOptions { telemetry: Some(tel), ..ServerOptions::default() },
    );
    let (b_core, b_handle) = start_backend(seed, ServerOptions::default());
    let (pcore, phandle) = start_proxy(vec![
        a_handle.local_addr().to_string(),
        b_handle.local_addr().to_string(),
    ]);
    wait_up(&pcore, 2);

    let mut client = connect(phandle.local_addr());
    // prime: with both backends idle the tie-break picks the first —
    // its response carries the soft-limit advertisement the proxy
    // folds into its routing state
    client.call(&words(0)).and_then(|p| client.wait(&p)).unwrap();
    let snap = pcore.stats().snapshot();
    assert_eq!(snap[0].requests, 1, "the idle tie-break must pick the first backend: {snap:?}");

    // every later one-shot sheds to B, charging a spill against A
    for i in 1..=6i64 {
        client.call(&words(i)).and_then(|p| client.wait(&p)).unwrap();
    }
    let snap = pcore.stats().snapshot();
    assert_eq!(snap[0].requests, 1, "the soft-limited backend kept taking work: {snap:?}");
    assert!(snap[1].requests >= 6, "{snap:?}");
    assert!(snap[0].spills >= 6, "the shed work was not counted as spills: {snap:?}");

    // and the fleet counters expose it on the metrics page
    let page = pcore.stats().to_prometheus();
    assert!(page.contains("impulse_proxy_spills_total"), "{page}");

    phandle.stop();
    pcore.shutdown();
    a_handle.stop();
    a_core.shutdown();
    b_handle.stop();
    b_core.shutdown();
}
