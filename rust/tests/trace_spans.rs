//! End-to-end tests of per-request lifecycle tracing: a traced server
//! records one complete span tree (decode → queue → batch → execute →
//! write) per served request, the span counts reconcile with the
//! telemetry response counters, the Chrome trace-event export
//! round-trips through the reader, tracing-off serving stays
//! bit-identical for non-negotiating clients, and the trace-echo
//! capability returns the server's own timing breakdown to the client.

use impulse::coordinator::{ServerOptions, WorkloadInput};
use impulse::data::SentimentArtifacts;
use impulse::macro_sim::MacroConfig;
use impulse::obs::trace::{load_trace_dir, write_rotation, Phase, Span, TraceRecorder};
use impulse::serve::{
    serve_tcp, FrameClient, ServeCore, TcpServeHandle, CAP_BACKPRESSURE, CAP_TRACE_ECHO,
    PROTOCOL_VERSION,
};
use impulse::snn::SentimentNetwork;
use impulse::telemetry::{Telemetry, TelemetryConfig};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

const VOCAB: i64 = 20; // SentimentArtifacts::synthetic vocabulary

fn start_core(seed: u64, opts: ServerOptions) -> (Arc<ServeCore>, TcpServeHandle) {
    let a = SentimentArtifacts::synthetic(seed);
    let core = Arc::new(
        ServeCore::start_with(opts, VOCAB, move || {
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        })
        .unwrap(),
    );
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(&core)).unwrap();
    (core, handle)
}

/// Drive `n` word requests over one framed connection and wait for
/// every response; returns when the server has closed after drain (so
/// all server-side spans, including the write phase, are recorded).
fn serve_requests(addr: std::net::SocketAddr, n: usize) {
    let mut client = FrameClient::connect(addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
    for i in 0..n {
        client.send_infer(i as u64, &[(i as i64) % VOCAB, 5, 7]).unwrap();
    }
    for _ in 0..n {
        let (id, res) = client.next_result().unwrap().expect("stream ended early");
        res.unwrap_or_else(|(c, m)| panic!("req {id} failed ({c}): {m}"));
    }
    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none(), "server must close after drain");
}

/// The tentpole contract: every request served while tracing is on
/// leaves exactly one span per lifecycle phase, all sharing one trace
/// id, with plausible timing (sequential phase starts, total duration
/// bounded by the observed wall time).
#[test]
fn traced_request_records_all_five_lifecycle_phases() {
    let recorder = Arc::new(TraceRecorder::new());
    let (core, handle) = start_core(
        31,
        ServerOptions {
            workers: 2,
            batch_size: 2,
            batch_deadline: Duration::from_millis(2),
            trace: Some(Arc::clone(&recorder)),
            ..ServerOptions::default()
        },
    );
    let n = 4usize;
    let t0 = Instant::now();
    serve_requests(handle.local_addr(), n);
    let wall_us = u64::try_from(t0.elapsed().as_micros()).unwrap();
    handle.stop();
    core.shutdown();

    let spans = recorder.drain();
    assert_eq!(recorder.dropped(), 0);
    assert_eq!(spans.len(), n * Phase::LIFECYCLE.len(), "five spans per request");

    let mut by_trace: BTreeMap<u64, Vec<Span>> = BTreeMap::new();
    for s in &spans {
        by_trace.entry(s.trace_id).or_default().push(*s);
    }
    assert_eq!(by_trace.len(), n, "one trace id per request");
    for (trace_id, tree) in by_trace {
        // exactly one span per lifecycle phase, starting in order
        // (starts can collide at µs resolution, hence <=)
        let mut ordered = Vec::new();
        for p in Phase::LIFECYCLE {
            let hits: Vec<&Span> = tree.iter().filter(|s| s.phase == p).collect();
            assert_eq!(hits.len(), 1, "trace {trace_id}: phase {p:?} must appear exactly once");
            ordered.push(*hits[0]);
        }
        for w in ordered.windows(2) {
            assert!(
                w[0].start_us <= w[1].start_us,
                "trace {trace_id}: {:?} must not start after {:?}",
                w[0].phase,
                w[1].phase
            );
        }
        let total: u64 = tree.iter().map(|s| s.dur_us).sum();
        assert!(
            total <= wall_us,
            "trace {trace_id}: phase durations ({total}us) exceed wall time ({wall_us}us)"
        );
        let exec = tree.iter().find(|s| s.phase == Phase::Execute).unwrap();
        assert!(exec.ok, "trace {trace_id}: successful request must mark execute ok");
        assert!(exec.cycles > 0, "trace {trace_id}: execute span missing cycle cost");
        assert!(exec.batch >= 1, "trace {trace_id}: execute span missing batch width");
        for w in tree.windows(2) {
            assert_eq!(w[0].trace_id, w[1].trace_id);
            assert_eq!(w[0].request_id, w[1].request_id, "phases must share the wire id");
        }
    }
}

/// Reconciliation: the recorder's execute spans and the telemetry
/// registry count the same population — one per response, ok and
/// error alike (a digits payload on a sentiment server errors inside
/// the engine, so it must still leave an execute span).
#[test]
fn execute_span_count_matches_telemetry_responses() {
    let recorder = Arc::new(TraceRecorder::new());
    let tele = Arc::new(Telemetry::new(TelemetryConfig::default()));
    let (core, handle) = start_core(
        37,
        ServerOptions {
            trace: Some(Arc::clone(&recorder)),
            telemetry: Some(Arc::clone(&tele)),
            ..ServerOptions::default()
        },
    );
    let mut client = FrameClient::connect(handle.local_addr()).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
    for i in 0..3u64 {
        client.send_infer(i, &[3, 1]).unwrap();
    }
    client.send_digits_infer(99, 28, 28, &[0.0; 784]).unwrap();
    let mut errs = 0;
    for _ in 0..4 {
        let (_, res) = client.next_result().unwrap().expect("stream ended early");
        errs += usize::from(res.is_err());
    }
    assert_eq!(errs, 1, "exactly the digits request must fail on this server");
    client.finish_writes().unwrap();
    assert!(client.next_frame().unwrap().is_none());
    handle.stop();
    core.shutdown();

    let spans = recorder.drain();
    let execs: Vec<&Span> = spans.iter().filter(|s| s.phase == Phase::Execute).collect();
    let snap = tele.snapshot();
    let (ok, err) = snap.kinds.iter().fold((0u64, 0u64), |(o, e), k| (o + k.ok, e + k.err));
    assert_eq!(execs.len() as u64, ok + err, "one execute span per telemetry response");
    assert_eq!(execs.iter().filter(|s| !s.ok).count() as u64, err);
    assert!(
        execs.iter().filter(|s| s.ok).all(|s| s.energy_fj > 0),
        "telemetry-attributed energy must ride on successful execute spans"
    );
}

/// The export pipeline: drained spans written as a rotation parse
/// back as a valid Chrome trace-event document with every field the
/// writer attached.
#[test]
fn chrome_trace_export_roundtrips_through_the_reader() {
    let recorder = Arc::new(TraceRecorder::new());
    let (core, handle) = start_core(
        41,
        ServerOptions {
            trace: Some(Arc::clone(&recorder)),
            ..ServerOptions::default()
        },
    );
    serve_requests(handle.local_addr(), 3);
    handle.stop();
    core.shutdown();
    let spans = recorder.drain();
    assert!(!spans.is_empty());

    let dir = std::env::temp_dir().join(format!("impulse-trace-spans-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = write_rotation(&dir, 0, &spans).unwrap();
    assert!(path.file_name().unwrap().to_str().unwrap().starts_with("trace-"));

    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("{\"traceEvents\":["), "must be a Chrome trace document");

    let events = load_trace_dir(&dir).unwrap();
    assert_eq!(events.len(), spans.len());
    for (e, s) in events.iter().zip(&spans) {
        assert_eq!(e.ph, "X", "writer emits complete events");
        assert_eq!(Phase::from_name(&e.name), Some(s.phase));
        assert_eq!(e.ts, s.start_us);
        assert_eq!(e.dur, s.dur_us);
        assert_eq!(e.trace_id, s.trace_id);
        assert_eq!(e.request_id, s.request_id);
        assert_eq!(e.conn, s.conn);
        assert_eq!(e.cycles, s.cycles);
        assert_eq!(e.energy_fj, s.energy_fj);
        assert_eq!(e.ok, s.ok);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The disabled path: for a client that negotiated nothing, a server
/// with tracing on answers bit-identically to one with `trace: None`
/// — same payload type, flags word, and payload bytes. Tracing must
/// not perturb the wire.
#[test]
fn tracing_is_invisible_to_non_negotiating_clients() {
    let seed = 43;
    let reqs: Vec<Vec<i64>> = vec![vec![3, 7, 5], vec![19], vec![2, 11, 6, 13]];
    let answers = |opts: ServerOptions| -> Vec<(u8, u64, u16, Vec<u8>)> {
        let (core, handle) = start_core(seed, opts);
        let mut client = FrameClient::connect(handle.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        assert_eq!(client.hello().unwrap(), PROTOCOL_VERSION);
        for (i, r) in reqs.iter().enumerate() {
            client.send_infer(i as u64, r).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..reqs.len() {
            let f = client.next_frame().unwrap().expect("stream ended early");
            got.push((f.payload_type as u8, f.request_id, f.flags, f.payload.clone()));
        }
        client.finish_writes().unwrap();
        assert!(client.next_frame().unwrap().is_none());
        handle.stop();
        core.shutdown();
        got.sort_by_key(|(_, id, _, _)| *id);
        got
    };
    let plain = answers(ServerOptions::default());
    let traced = answers(ServerOptions {
        trace: Some(Arc::new(TraceRecorder::new())),
        ..ServerOptions::default()
    });
    assert_eq!(plain, traced, "tracing must not change a single wire byte");
    assert!(plain.iter().all(|(_, _, flags, _)| *flags == 0));
}

/// The negotiated path: a client that was granted `CAP_TRACE_ECHO`
/// and flags its requests gets the per-phase timing trailer back on a
/// traced server — and `None` on an untraced one.
#[test]
fn trace_echo_returns_the_servers_timing_breakdown() {
    let run = |trace: Option<Arc<TraceRecorder>>| {
        let (core, handle) = start_core(
            47,
            ServerOptions {
                trace,
                ..ServerOptions::default()
            },
        );
        let mut client = FrameClient::connect(handle.local_addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let (version, granted) =
            client.hello_with_caps(CAP_BACKPRESSURE | CAP_TRACE_ECHO).unwrap();
        assert_eq!(version, PROTOCOL_VERSION);
        assert_ne!(granted & CAP_TRACE_ECHO, 0, "server must grant the echo capability");
        client.set_trace_echo(true);
        let p = client.call(&WorkloadInput::Words(vec![3, 7, 5])).unwrap();
        let (out, echo) = client.wait_with_trace(&p).unwrap();
        assert!(out.cycles > 0, "response must carry cost accounting");
        handle.stop();
        core.shutdown();
        echo
    };
    run(Some(Arc::new(TraceRecorder::new())))
        .expect("traced server must echo the timing breakdown");
    assert!(run(None).is_none(), "an untraced server has no breakdown to echo");
}
