//! Frame-codec conformance tests: the worked hex examples of
//! `docs/PROTOCOL.md` are pinned here byte-for-byte (doc and codec
//! must change in lockstep), plus property tests for round-tripping
//! and rejection of truncated/corrupted/oversized frames.

use impulse::proptest_lite::forall_ctx;
use impulse::serve::{
    crc32, decode_backpressure, decode_digits_request, decode_digits_response, decode_error,
    decode_infer_request, decode_infer_response, decode_stats_response, decode_stream_ack,
    decode_stream_append, decode_stream_ref, encode_backpressure, encode_digits_request,
    encode_infer_request, encode_stats_request, encode_stats_response, encode_stream_ack,
    encode_stream_append, encode_stream_ref, error_payload, hello_caps_payload, hello_payload,
    Backpressure, Decoded, ErrorCode, Frame, PayloadType, WireError, WireStreamAck, CRC_LEN,
    FLAG_SOFT_LIMIT, FLAG_TELEMETRY, HEADER_LEN, MAX_PAYLOAD, PROTOCOL_VERSION, STREAM_OP_APPEND,
    STREAM_OP_CLOSE, STREAM_OP_OPEN,
};
use impulse::coordinator::{WorkloadInput, WorkloadKind};
use impulse::telemetry::{KindStats, StatsSnapshot, Transport, TransportStats};

fn hex(s: &str) -> Vec<u8> {
    s.split_whitespace()
        .map(|b| u8::from_str_radix(b, 16).unwrap())
        .collect()
}

fn decode_one(bytes: &[u8]) -> Frame {
    match Frame::decode(bytes).unwrap() {
        Decoded::Frame(f, used) => {
            assert_eq!(used, bytes.len(), "frame must consume the whole example");
            f
        }
        other => panic!("expected a complete frame, got {other:?}"),
    }
}

/// PROTOCOL.md §6, example 1: `InferRequest`, request id 7, word ids
/// [3, 1, 4].
#[test]
fn protocol_md_worked_example_request() {
    let wire = hex(
        "49 4D 50 31 01 10 00 00 00 00 00 00 00 00 00 07 00 00 00 0E \
         00 03 00 00 00 03 00 00 00 01 00 00 00 04 70 DD 68 B1",
    );
    let f = Frame::new(PayloadType::InferRequest, 7, encode_infer_request(&[3, 1, 4]).unwrap());
    assert_eq!(f.encode(), wire, "encoder must produce the documented bytes");
    let g = decode_one(&wire);
    assert_eq!(g.version, PROTOCOL_VERSION);
    assert_eq!(g.payload_type, PayloadType::InferRequest);
    assert_eq!(g.request_id, 7);
    assert_eq!(decode_infer_request(&g.payload).unwrap(), vec![3, 1, 4]);
}

/// PROTOCOL.md §6, example 2: the matching `InferResponse` (pred 1,
/// v_out 42, cycles 35200, latency 181 µs, batch 1, worker 0).
#[test]
fn protocol_md_worked_example_response() {
    let wire = hex(
        "49 4D 50 31 01 11 00 00 00 00 00 00 00 00 00 07 00 00 00 1D \
         01 00 00 00 00 00 00 00 2A 00 00 00 00 00 00 89 80 \
         00 00 00 00 00 00 00 B5 00 01 00 00 0D AA 3F 31",
    );
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::InferResponse);
    assert_eq!(g.request_id, 7);
    let r = decode_infer_response(&g.payload).unwrap();
    assert_eq!(r.pred, 1);
    assert_eq!(r.v_out, 42);
    assert_eq!(r.cycles, 35200);
    assert_eq!(r.latency_us, 181);
    assert_eq!(r.batch, 1);
    assert_eq!(r.worker, 0);
}

/// PROTOCOL.md §6, examples 3–5: Hello, HelloAck, and an Error frame.
#[test]
fn protocol_md_worked_example_handshake_and_error() {
    let hello_wire = hex(
        "49 4D 50 31 01 01 00 00 00 00 00 00 00 00 00 00 00 00 00 02 01 01 A2 4A 7D 2B",
    );
    assert_eq!(Frame::new(PayloadType::Hello, 0, hello_payload(1, 1)).encode(), hello_wire);

    let ack_wire = hex(
        "49 4D 50 31 01 02 00 00 00 00 00 00 00 00 00 00 00 00 00 01 01 20 83 CE 35",
    );
    assert_eq!(Frame::new(PayloadType::HelloAck, 0, vec![1]).encode(), ack_wire);

    let err_wire = hex(
        "49 4D 50 31 01 7F 00 00 00 00 00 00 00 00 00 09 00 00 00 18 \
         00 07 00 14 77 6F 72 64 20 69 64 20 6F 75 74 20 6F 66 20 72 61 6E 67 65 \
         BD 6F 8B 78",
    );
    let f = Frame::new(
        PayloadType::Error,
        9,
        error_payload(ErrorCode::InferenceFailed, "word id out of range"),
    );
    assert_eq!(f.encode(), err_wire);
    let g = decode_one(&err_wire);
    let (code, msg) = decode_error(&g.payload).unwrap();
    assert_eq!(code, ErrorCode::InferenceFailed.as_u16());
    assert_eq!(msg, "word id out of range");
}

/// PROTOCOL.md §3: the CRC is IEEE 802.3 (zlib-compatible).
#[test]
fn crc_is_zlib_compatible() {
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
}

/// Property: any frame round-trips bit-exactly through encode/decode.
#[test]
fn prop_roundtrip_random_frames() {
    let types = [
        PayloadType::Hello,
        PayloadType::HelloAck,
        PayloadType::InferRequest,
        PayloadType::InferResponse,
        PayloadType::Error,
    ];
    forall_ctx(
        300,
        0x0F7A,
        |rng| {
            let ty = types[rng.gen_range(types.len() as u64) as usize];
            let id = rng.next_u64();
            let n = rng.gen_range(200) as usize;
            let payload: Vec<u8> = (0..n).map(|_| rng.gen_range(256) as u8).collect();
            Frame::new(ty, id, payload)
        },
        |f| {
            let bytes = f.encode();
            match Frame::decode(&bytes) {
                Ok(Decoded::Frame(g, used)) if g == *f && used == bytes.len() => Ok(()),
                other => Err(format!("roundtrip failed: {other:?}")),
            }
        },
    );
}

/// Property: no prefix of a valid frame ever decodes to a frame, and
/// the codec always asks for at least one more byte than it has.
#[test]
fn prop_truncation_never_yields_a_frame() {
    forall_ctx(
        100,
        0x7210,
        |rng| {
            let n = rng.gen_range(64) as usize;
            let payload: Vec<u8> = (0..n).map(|_| rng.gen_range(256) as u8).collect();
            let cut = rng.gen_range((HEADER_LEN + n + CRC_LEN) as u64) as usize;
            (Frame::new(PayloadType::InferRequest, rng.next_u64(), payload), cut)
        },
        |(f, cut)| {
            let bytes = f.encode();
            match Frame::decode(&bytes[..*cut]) {
                Ok(Decoded::NeedMore(want)) if want > *cut => Ok(()),
                other => Err(format!("prefix of {cut} bytes gave {other:?}")),
            }
        },
    );
}

/// Property: flipping any single byte of a frame never yields the
/// original back; payload-region flips are caught by the CRC.
#[test]
fn prop_single_byte_corruption_is_detected() {
    forall_ctx(
        60,
        0xC0DE,
        |rng| {
            let n = 1 + rng.gen_range(40) as usize;
            let payload: Vec<u8> = (0..n).map(|_| rng.gen_range(256) as u8).collect();
            let f = Frame::new(PayloadType::InferResponse, rng.next_u64(), payload);
            let pos = rng.gen_range(f.encoded_len() as u64) as usize;
            let bit = 1u8 << rng.gen_range(8);
            (f, pos, bit)
        },
        |(f, pos, bit)| {
            let mut bytes = f.encode();
            bytes[*pos] ^= bit;
            match Frame::decode(&bytes) {
                Ok(Decoded::Frame(g, _)) if g == *f => {
                    Err(format!("flip at {pos} went undetected"))
                }
                // a flip in the length field may legitimately ask for
                // more bytes; anything else must be an error or a
                // differently-keyed frame (impossible: CRC covers all)
                _ => Ok(()),
            }
        },
    );
}

/// Payload-byte corruption specifically reports BadCrc (PROTOCOL.md
/// §5: the checksum is verified before the payload is interpreted).
#[test]
fn payload_corruption_reports_bad_crc() {
    let f = Frame::new(PayloadType::InferRequest, 11, encode_infer_request(&[5, 6]).unwrap());
    for off in HEADER_LEN..HEADER_LEN + f.payload.len() {
        let mut bytes = f.encode();
        bytes[off] ^= 0x01;
        assert!(
            matches!(Frame::decode(&bytes), Err(WireError::BadCrc { .. })),
            "offset {off}"
        );
    }
}

/// Frames claiming more than MAX_PAYLOAD are rejected from the header
/// alone; a maximum-size payload is accepted.
#[test]
fn oversized_rejected_max_size_accepted() {
    let mut bytes = Frame::new(PayloadType::InferRequest, 1, vec![0; 8]).encode();
    bytes[16..20].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_be_bytes());
    assert!(matches!(
        Frame::decode(&bytes[..HEADER_LEN]),
        Err(WireError::Oversized(_))
    ));

    let big = Frame::new(PayloadType::Error, 2, vec![0xAB; MAX_PAYLOAD]);
    let wire = big.encode();
    match Frame::decode(&wire).unwrap() {
        Decoded::Frame(g, used) => {
            assert_eq!(used, wire.len());
            assert_eq!(g.payload.len(), MAX_PAYLOAD);
        }
        other => panic!("max-size frame rejected: {other:?}"),
    }
}

/// PROTOCOL.md §6, digits example 1: `DigitsInferRequest`, request id
/// 12, a 2×2 image `[0.0, 0.5, 1.0, -1.0]`.
#[test]
fn protocol_md_worked_example_digits_request() {
    let wire = hex(
        "49 4D 50 31 01 12 00 00 00 00 00 00 00 00 00 0C \
         00 00 00 12 02 02 00 00 00 00 3F 00 00 00 3F 80 \
         00 00 BF 80 00 00 85 CE EF 12",
    );
    let f = Frame::new(
        PayloadType::DigitsInferRequest,
        12,
        encode_digits_request(2, 2, &[0.0, 0.5, 1.0, -1.0]).unwrap(),
    );
    assert_eq!(f.encode(), wire, "encoder must produce the documented bytes");
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::DigitsInferRequest);
    assert_eq!(g.request_id, 12);
    assert_eq!(
        decode_digits_request(&g.payload).unwrap(),
        (2, 2, vec![0.0, 0.5, 1.0, -1.0])
    );
}

/// PROTOCOL.md §6, digits example 2: the matching
/// `DigitsInferResponse` (pred 3, ten potentials, cycles 51234,
/// latency 181 µs, batch 2, worker 1).
#[test]
fn protocol_md_worked_example_digits_response() {
    let wire = hex(
        "49 4D 50 31 01 13 00 00 00 00 00 00 00 00 00 0C \
         00 00 00 66 03 0A 00 00 00 00 00 00 00 00 FF FF \
         FF FF FF FF FF FB 00 00 00 00 00 00 00 0C 00 00 \
         00 00 00 00 00 28 00 00 00 00 00 00 00 07 FF FF \
         FF FF FF FF FF FE 00 00 00 00 00 00 00 00 00 00 \
         00 00 00 00 00 03 00 00 00 00 00 00 00 09 00 00 \
         00 00 00 00 00 01 00 00 00 00 00 00 C8 22 00 00 \
         00 00 00 00 00 B5 00 02 00 01 08 98 B3 23",
    );
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::DigitsInferResponse);
    assert_eq!(g.request_id, 12);
    let r = decode_digits_response(&g.payload).unwrap();
    assert_eq!(r.pred, 3);
    assert_eq!(r.v_all, vec![0, -5, 12, 40, 7, -2, 0, 3, 9, 1]);
    assert_eq!(r.cycles, 51234);
    assert_eq!(r.latency_us, 181);
    assert_eq!((r.batch, r.worker), (2, 1));
}

/// The StatsSnapshot the §6.2 worked examples pin: small but
/// exercising every section of the payload.
fn pinned_stats_snapshot() -> StatsSnapshot {
    StatsSnapshot {
        queue_depth: 2,
        queue_soft_limit: 8,
        soft_limited: false,
        batches: 3,
        batch_lanes: 5,
        batch_lane_capacity: 39,
        kinds: vec![KindStats {
            kind: WorkloadKind::Sentiment,
            submitted: 5,
            ok: 5,
            err: 0,
            cycles: 35200,
            energy_fj: 35555,
            edp_js: 1.5,
            input_units: 15,
            input_active: 12,
        }],
        instr: vec![(0, 1200), (2, 300)],
        transports: vec![TransportStats {
            transport: Transport::Tcp,
            count: 5,
            sum_us: 905,
            buckets: vec![0, 1, 3, 1],
        }],
    }
}

/// PROTOCOL.md §6.2, example 1: `StatsRequest`, request id 9, empty
/// payload.
#[test]
fn protocol_md_worked_example_stats_request() {
    let wire = hex("49 4D 50 31 01 14 00 00 00 00 00 00 00 00 00 09 00 00 00 00 FF AE EF 08");
    let f = Frame::new(PayloadType::StatsRequest, 9, encode_stats_request());
    assert_eq!(f.encode(), wire, "encoder must produce the documented bytes");
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::StatsRequest);
    assert_eq!(g.request_id, 9);
    assert!(g.payload.is_empty());
}

/// PROTOCOL.md §6.2, example 2: the matching `StatsResponse` with a
/// backpressure flags word (telemetry + soft-limit bits, depth 2).
#[test]
fn protocol_md_worked_example_stats_response() {
    let wire = hex(
        "49 4D 50 31 01 15 C0 02 00 00 00 00 00 00 00 09 \
         00 00 00 B3 01 00 00 00 00 00 00 00 00 02 00 00 \
         00 00 00 00 00 08 00 00 00 00 00 00 00 00 03 00 \
         00 00 00 00 00 00 05 00 00 00 00 00 00 00 27 01 \
         00 00 00 00 00 00 00 00 05 00 00 00 00 00 00 00 \
         05 00 00 00 00 00 00 00 00 00 00 00 00 00 00 89 \
         80 00 00 00 00 00 00 8A E3 3F F8 00 00 00 00 00 \
         00 00 00 00 00 00 00 00 0F 00 00 00 00 00 00 00 \
         0C 02 00 00 00 00 00 00 00 04 B0 02 00 00 00 00 \
         00 00 01 2C 01 00 00 00 00 00 00 00 00 05 00 00 \
         00 00 00 00 03 89 04 00 00 00 00 00 00 00 00 00 \
         00 00 00 00 00 00 01 00 00 00 00 00 00 00 03 00 \
         00 00 00 00 00 00 01 88 9C 26 2B",
    );
    let snap = pinned_stats_snapshot();
    let f = Frame::new(PayloadType::StatsResponse, 9, encode_stats_response(&snap))
        .with_flags(encode_backpressure(2, true));
    assert_eq!(f.encode(), wire, "encoder must produce the documented bytes");
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::StatsResponse);
    assert_eq!(g.request_id, 9);
    assert_eq!(g.flags, FLAG_TELEMETRY | FLAG_SOFT_LIMIT | 2);
    assert_eq!(
        decode_backpressure(g.flags),
        Some(Backpressure { queue_depth: 2, soft_limited: true })
    );
    assert_eq!(decode_stats_response(&g.payload).unwrap(), snap);
}

/// PROTOCOL.md §6.2, examples 3–4: the extended (capability) Hello
/// and its 2-byte HelloAck.
#[test]
fn protocol_md_worked_example_extended_hello() {
    let hello_wire = hex(
        "49 4D 50 31 01 01 00 00 00 00 00 00 00 00 00 00 \
         00 00 00 03 01 01 01 B1 A7 0B 43",
    );
    assert_eq!(
        Frame::new(PayloadType::Hello, 0, hello_caps_payload(1, 1, 0x01)).encode(),
        hello_wire
    );
    let ack_wire = hex(
        "49 4D 50 31 01 02 00 00 00 00 00 00 00 00 00 00 \
         00 00 00 02 01 01 F1 D0 26 AF",
    );
    assert_eq!(Frame::new(PayloadType::HelloAck, 0, vec![1, 1]).encode(), ack_wire);
}

/// PROTOCOL.md §6.2, example 5: the §6 `InferResponse` re-sent with a
/// backpressure flags word (depth 3, soft limit clear) — only the
/// flags bytes and the CRC differ from the pinned v1 frame.
#[test]
fn protocol_md_worked_example_flagged_response() {
    let wire = hex(
        "49 4D 50 31 01 11 80 03 00 00 00 00 00 00 00 07 \
         00 00 00 1D 01 00 00 00 00 00 00 00 2A 00 00 00 \
         00 00 00 89 80 00 00 00 00 00 00 00 B5 00 01 00 \
         00 65 0D 76 35",
    );
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::InferResponse);
    assert_eq!(
        decode_backpressure(g.flags),
        Some(Backpressure { queue_depth: 3, soft_limited: false })
    );
    let r = decode_infer_response(&g.payload).unwrap();
    assert_eq!((r.pred, r.v_out, r.cycles), (1, 42, 35200));
    // identical to the §6 frame except bytes 6–7 and the CRC
    let v1 = hex(
        "49 4D 50 31 01 11 00 00 00 00 00 00 00 00 00 07 00 00 00 1D \
         01 00 00 00 00 00 00 00 2A 00 00 00 00 00 00 89 80 \
         00 00 00 00 00 00 00 B5 00 01 00 00 0D AA 3F 31",
    );
    assert_eq!(wire[..6], v1[..6]);
    assert_eq!(wire[8..wire.len() - CRC_LEN], v1[8..v1.len() - CRC_LEN]);
}

/// Property: stats payloads round-trip bit-exactly through the codec
/// for arbitrary counter values.
#[test]
fn prop_stats_payload_roundtrips() {
    forall_ctx(
        120,
        0x57A7,
        |rng| StatsSnapshot {
            queue_depth: rng.next_u64(),
            queue_soft_limit: rng.next_u64(),
            soft_limited: rng.gen_range(2) == 1,
            batches: rng.next_u64(),
            batch_lanes: rng.next_u64(),
            batch_lane_capacity: rng.next_u64(),
            kinds: vec![
                KindStats {
                    kind: WorkloadKind::Sentiment,
                    submitted: rng.next_u64(),
                    ok: rng.next_u64(),
                    err: rng.next_u64(),
                    cycles: rng.next_u64(),
                    energy_fj: rng.next_u64(),
                    edp_js: rng.gen_range(1 << 30) as f64 * 1e-12,
                    input_units: rng.next_u64(),
                    input_active: rng.next_u64(),
                },
                KindStats {
                    kind: WorkloadKind::Digits,
                    submitted: rng.next_u64(),
                    ok: 0,
                    err: 0,
                    cycles: 0,
                    energy_fj: 0,
                    edp_js: 0.0,
                    input_units: 0,
                    input_active: 0,
                },
            ],
            instr: (0..7).map(|c| (c as u8, rng.next_u64())).collect(),
            transports: vec![TransportStats {
                transport: Transport::Stdio,
                count: rng.next_u64(),
                sum_us: rng.next_u64(),
                buckets: (0..rng.gen_range(29) as usize).map(|_| rng.next_u64()).collect(),
            }],
        },
        |snap| {
            let payload = encode_stats_response(snap);
            match decode_stats_response(&payload) {
                Ok(got) if got == *snap => Ok(()),
                other => Err(format!("roundtrip failed: {other:?}")),
            }
        },
    );
}

/// PROTOCOL.md §6.3, example 1: `StreamOpen`, request id 21 (the
/// request id becomes the stream id), empty payload — and the
/// matching `StreamAck` (op 0, stream 21, lane 0, cycles 0).
#[test]
fn protocol_md_worked_example_stream_open_and_ack() {
    let open_wire = hex(
        "49 4D 50 31 01 16 00 00 00 00 00 00 00 00 00 15 \
         00 00 00 00 F2 38 24 1A",
    );
    let f = Frame::new(PayloadType::StreamOpen, 21, Vec::new());
    assert_eq!(f.encode(), open_wire, "encoder must produce the documented bytes");
    let g = decode_one(&open_wire);
    assert_eq!(g.payload_type, PayloadType::StreamOpen);
    assert_eq!(g.request_id, 21);
    assert!(g.payload.is_empty());

    let ack_wire = hex(
        "49 4D 50 31 01 1A 00 00 00 00 00 00 00 00 00 15 \
         00 00 00 13 00 00 00 00 00 00 00 00 15 00 00 00 \
         00 00 00 00 00 00 00 C4 CC 5C FF",
    );
    let ack = WireStreamAck { op: STREAM_OP_OPEN, stream_id: 21, lane: 0, cycles: 0 };
    assert_eq!(Frame::new(PayloadType::StreamAck, 21, encode_stream_ack(&ack)).encode(), ack_wire);
    let g = decode_one(&ack_wire);
    assert_eq!(g.payload_type, PayloadType::StreamAck);
    assert_eq!(decode_stream_ack(&g.payload).unwrap(), ack);
}

/// PROTOCOL.md §6.3, example 2: `StreamAppend` of word ids [3, 1, 4]
/// to stream 21 — the body after the 9-byte stream header is
/// byte-for-byte the §4.4 one-shot request layout — and the matching
/// `StreamAck` (op 1, cumulative cycles 35200).
#[test]
fn protocol_md_worked_example_stream_append_words() {
    let wire = hex(
        "49 4D 50 31 01 17 00 00 00 00 00 00 00 00 00 16 \
         00 00 00 17 00 00 00 00 00 00 00 15 00 00 03 00 \
         00 00 03 00 00 00 01 00 00 00 04 3E F1 8C 7B",
    );
    let chunk = WorkloadInput::Words(vec![3, 1, 4]);
    let payload = encode_stream_append(21, &chunk).unwrap();
    assert_eq!(Frame::new(PayloadType::StreamAppend, 22, payload).encode(), wire);
    let g = decode_one(&wire);
    assert_eq!(g.payload_type, PayloadType::StreamAppend);
    assert_eq!(g.request_id, 22);
    assert_eq!(decode_stream_append(&g.payload).unwrap(), (21, chunk));
    // the embedded body is exactly the one-shot encoding
    assert_eq!(g.payload[9..], encode_infer_request(&[3, 1, 4]).unwrap());

    let ack_wire = hex(
        "49 4D 50 31 01 1A 00 00 00 00 00 00 00 00 00 16 \
         00 00 00 13 01 00 00 00 00 00 00 00 15 00 00 00 \
         00 00 00 00 00 89 80 4C C9 D5 AD",
    );
    let ack = WireStreamAck { op: STREAM_OP_APPEND, stream_id: 21, lane: 0, cycles: 35200 };
    assert_eq!(Frame::new(PayloadType::StreamAck, 22, encode_stream_ack(&ack)).encode(), ack_wire);
    assert_eq!(decode_stream_ack(&decode_one(&ack_wire).payload).unwrap(), ack);
}

/// PROTOCOL.md §6.3, example 3: `StreamAppend` of one 2×2 image frame
/// (kind byte 1, §4.5 body layout) to stream 21.
#[test]
fn protocol_md_worked_example_stream_append_image() {
    let wire = hex(
        "49 4D 50 31 01 17 00 00 00 00 00 00 00 00 00 17 \
         00 00 00 1B 00 00 00 00 00 00 00 15 01 02 02 00 \
         00 00 00 3F 00 00 00 3F 80 00 00 BF 80 00 00 5F \
         F2 77 CB",
    );
    let chunk = WorkloadInput::Image { h: 2, w: 2, pixels: vec![0.0, 0.5, 1.0, -1.0] };
    let payload = encode_stream_append(21, &chunk).unwrap();
    assert_eq!(Frame::new(PayloadType::StreamAppend, 23, payload).encode(), wire);
    let g = decode_one(&wire);
    assert_eq!(decode_stream_append(&g.payload).unwrap(), (21, chunk));
    assert_eq!(g.payload[9..], encode_digits_request(2, 2, &[0.0, 0.5, 1.0, -1.0]).unwrap());
}

/// PROTOCOL.md §6.3, examples 4–6: `StreamReadOut` and `StreamClose`
/// both carry the bare 8-byte stream id; the close is acknowledged
/// with the session's final cumulative cycles.
#[test]
fn protocol_md_worked_example_stream_read_out_and_close() {
    let readout_wire = hex(
        "49 4D 50 31 01 18 00 00 00 00 00 00 00 00 00 18 \
         00 00 00 08 00 00 00 00 00 00 00 15 15 2C 7E 29",
    );
    let f = Frame::new(PayloadType::StreamReadOut, 24, encode_stream_ref(21));
    assert_eq!(f.encode(), readout_wire, "encoder must produce the documented bytes");
    let g = decode_one(&readout_wire);
    assert_eq!(g.payload_type, PayloadType::StreamReadOut);
    assert_eq!(decode_stream_ref(&g.payload).unwrap(), 21);

    let close_wire = hex(
        "49 4D 50 31 01 19 00 00 00 00 00 00 00 00 00 19 \
         00 00 00 08 00 00 00 00 00 00 00 15 53 C9 4D 78",
    );
    assert_eq!(Frame::new(PayloadType::StreamClose, 25, encode_stream_ref(21)).encode(), close_wire);
    assert_eq!(decode_one(&close_wire).payload_type, PayloadType::StreamClose);

    let ack_wire = hex(
        "49 4D 50 31 01 1A 00 00 00 00 00 00 00 00 00 19 \
         00 00 00 13 02 00 00 00 00 00 00 00 15 00 00 00 \
         00 00 00 00 00 89 80 0C 8A 58 CD",
    );
    let ack = WireStreamAck { op: STREAM_OP_CLOSE, stream_id: 21, lane: 0, cycles: 35200 };
    assert_eq!(Frame::new(PayloadType::StreamAck, 25, encode_stream_ack(&ack)).encode(), ack_wire);
    assert_eq!(decode_stream_ack(&decode_one(&ack_wire).payload).unwrap(), ack);
}

/// PROTOCOL.md §6.3, example 7: the `Error` frame answering an
/// operation on an unknown/expired stream (code 11, `StreamExpired`).
#[test]
fn protocol_md_worked_example_stream_expired_error() {
    let wire = hex(
        "49 4D 50 31 01 7F 00 00 00 00 00 00 00 00 00 18 \
         00 00 00 2C 00 0B 00 28 73 74 72 65 61 6D 20 32 \
         31 20 69 73 20 75 6E 6B 6E 6F 77 6E 2C 20 63 6C \
         6F 73 65 64 2C 20 6F 72 20 65 78 70 69 72 65 64 \
         E7 33 1C 31",
    );
    let f = Frame::new(
        PayloadType::Error,
        24,
        error_payload(ErrorCode::StreamExpired, "stream 21 is unknown, closed, or expired"),
    );
    assert_eq!(f.encode(), wire);
    let (code, msg) = decode_error(&decode_one(&wire).payload).unwrap();
    assert_eq!(code, ErrorCode::StreamExpired.as_u16());
    assert_eq!(msg, "stream 21 is unknown, closed, or expired");
}

/// Stream payload codecs reject malformed inputs with the right codes.
#[test]
fn stream_payload_rejection() {
    // append: under 9 bytes / unknown kind byte
    assert_eq!(decode_stream_append(&[0; 8]).unwrap_err().code, ErrorCode::Malformed);
    let mut p = encode_stream_append(1, &WorkloadInput::Words(vec![2])).unwrap();
    p[8] = 9; // no such chunk kind
    assert_eq!(decode_stream_append(&p).unwrap_err().code, ErrorCode::Malformed);
    // ref: must be exactly 8 bytes
    assert_eq!(decode_stream_ref(&[0; 7]).unwrap_err().code, ErrorCode::Malformed);
    assert_eq!(decode_stream_ref(&[0; 9]).unwrap_err().code, ErrorCode::Malformed);
    // ack: must be exactly 19 bytes with a known op byte
    assert_eq!(decode_stream_ack(&[0; 18]).unwrap_err().code, ErrorCode::Malformed);
    let mut a = encode_stream_ack(&WireStreamAck {
        op: STREAM_OP_OPEN,
        stream_id: 1,
        lane: 0,
        cycles: 0,
    });
    a[0] = 3; // op byte past StreamClose
    assert_eq!(decode_stream_ack(&a).unwrap_err().code, ErrorCode::Malformed);
}

/// The stream discriminants and error codes are pinned on the wire.
#[test]
fn stream_discriminants_and_error_codes() {
    assert_eq!(PayloadType::StreamOpen.as_u8(), 0x16);
    assert_eq!(PayloadType::StreamAppend.as_u8(), 0x17);
    assert_eq!(PayloadType::StreamReadOut.as_u8(), 0x18);
    assert_eq!(PayloadType::StreamClose.as_u8(), 0x19);
    assert_eq!(PayloadType::StreamAck.as_u8(), 0x1A);
    assert_eq!(PayloadType::from_u8(0x16), Some(PayloadType::StreamOpen));
    assert_eq!(PayloadType::from_u8(0x17), Some(PayloadType::StreamAppend));
    assert_eq!(PayloadType::from_u8(0x18), Some(PayloadType::StreamReadOut));
    assert_eq!(PayloadType::from_u8(0x19), Some(PayloadType::StreamClose));
    assert_eq!(PayloadType::from_u8(0x1A), Some(PayloadType::StreamAck));
    assert_eq!(ErrorCode::StreamExpired.as_u16(), 11);
    assert_eq!(ErrorCode::StreamLimit.as_u16(), 12);
    assert_eq!(ErrorCode::from_u16(11), Some(ErrorCode::StreamExpired));
    assert_eq!(ErrorCode::from_u16(12), Some(ErrorCode::StreamLimit));
}

/// Property: stream append payloads round-trip for both chunk kinds.
#[test]
fn prop_stream_append_roundtrips() {
    forall_ctx(
        120,
        0x5EED,
        |rng| {
            let stream_id = rng.next_u64();
            let chunk = if rng.gen_range(2) == 0 {
                let n = 1 + rng.gen_range(24) as usize;
                WorkloadInput::Words(
                    (0..n).map(|_| rng.gen_range(30_000) as i64).collect(),
                )
            } else {
                let h = 1 + rng.gen_range(6) as usize;
                let w = 1 + rng.gen_range(6) as usize;
                WorkloadInput::Image {
                    h,
                    w,
                    pixels: (0..h * w).map(|_| rng.gen_range(256) as f32 / 255.0).collect(),
                }
            };
            (stream_id, chunk)
        },
        |(stream_id, chunk)| {
            let p = encode_stream_append(*stream_id, chunk).map_err(|e| e.to_string())?;
            match decode_stream_append(&p) {
                Ok((sid, got)) if sid == *stream_id && got == *chunk => Ok(()),
                other => Err(format!("roundtrip failed: {other:?}")),
            }
        },
    );
}

/// The new v1 discriminants and error code round-trip on the wire.
#[test]
fn digits_discriminants_and_request_too_large_code() {
    assert_eq!(PayloadType::DigitsInferRequest.as_u8(), 0x12);
    assert_eq!(PayloadType::DigitsInferResponse.as_u8(), 0x13);
    assert_eq!(PayloadType::from_u8(0x12), Some(PayloadType::DigitsInferRequest));
    assert_eq!(PayloadType::from_u8(0x13), Some(PayloadType::DigitsInferResponse));
    assert_eq!(PayloadType::StatsRequest.as_u8(), 0x14);
    assert_eq!(PayloadType::StatsResponse.as_u8(), 0x15);
    assert_eq!(PayloadType::from_u8(0x14), Some(PayloadType::StatsRequest));
    assert_eq!(PayloadType::from_u8(0x15), Some(PayloadType::StatsResponse));
    assert_eq!(ErrorCode::RequestTooLarge.as_u16(), 10);
    assert_eq!(ErrorCode::from_u16(10), Some(ErrorCode::RequestTooLarge));
}
