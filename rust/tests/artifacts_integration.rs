//! Integration tests over the AOT artifact bundle: the Rust macro
//! stack must agree with the Python/Pallas reference *bit-for-bit*, and
//! the PJRT-executed HLO must agree with both.
//!
//! These tests are skipped (with a notice) if `make artifacts` has not
//! run yet.

use impulse::data::{artifacts_available, artifacts_dir, KernelVector, SentimentArtifacts};
use impulse::isa::NeuronType;
use impulse::macro_sim::MacroConfig;
use impulse::neuron::{GoldenLayer, NeuronParams};
use impulse::snn::{FcLayer, LayerParams, SentimentNetwork};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn neuron_of(mode: i64) -> NeuronType {
    match mode {
        0 => NeuronType::IF,
        1 => NeuronType::LIF,
        _ => NeuronType::RMP,
    }
}

/// L1 cross-check: exported Pallas/ref test vectors vs the Rust golden
/// neuron model.
#[test]
fn kernel_vectors_match_golden_model() {
    require_artifacts!();
    let vectors = KernelVector::load_all(artifacts_dir()).expect("load kernel vectors");
    assert!(vectors.len() >= 4, "expected ≥4 vectors");
    for kv in &vectors {
        let params = NeuronParams {
            neuron: neuron_of(kv.mode),
            threshold: kv.threshold,
            reset: 0,
            leak: kv.leak,
        };
        for (b, batch_spikes) in kv.spikes.iter().enumerate() {
            let mut layer = GoldenLayer::new(params, kv.weights.clone());
            // seed V state
            for (n, st) in layer.state.iter_mut().enumerate() {
                st.v = kv.v[b][n];
            }
            let in_spikes: Vec<bool> = batch_spikes.iter().map(|&s| s == 1).collect();
            let out = layer.step(&in_spikes);
            let got_v = layer.potentials();
            assert_eq!(got_v, kv.v_next[b], "{}: batch {b} V mismatch", kv.name);
            let want_s: Vec<bool> = kv.spikes_out[b].iter().map(|&s| s == 1).collect();
            assert_eq!(out, want_s, "{}: batch {b} spike mismatch", kv.name);
        }
    }
}

/// L1 → macro: the same vectors executed on the *mapped bit-level
/// macro* (the silicon-faithful path).
#[test]
fn kernel_vectors_match_macro_simulation() {
    require_artifacts!();
    let vectors = KernelVector::load_all(artifacts_dir()).expect("load kernel vectors");
    // the bit-level engine is slow; the small vector suffices there,
    // the rest run on the fast engine (which lib tests prove identical)
    for kv in &vectors {
        let cfg = if kv.weights.len() <= 16 {
            MacroConfig::lockstep()
        } else {
            MacroConfig::fast()
        };
        let params = LayerParams {
            neuron: neuron_of(kv.mode),
            threshold: kv.threshold,
            reset: 0,
            leak: kv.leak,
        };
        for (b, batch_spikes) in kv.spikes.iter().enumerate() {
            let mut layer = FcLayer::new(&kv.weights, params, cfg).expect("map layer");
            // Seed V by replaying: write potentials via an initial
            // "current injection" is not possible directly, so instead
            // check from zero state: run one step with the vector's
            // spikes on zero-V and compare against golden on zero-V.
            let mut golden = GoldenLayer::new(
                NeuronParams {
                    neuron: params.neuron,
                    threshold: params.threshold,
                    reset: 0,
                    leak: params.leak,
                },
                kv.weights.clone(),
            );
            let in_spikes: Vec<bool> = batch_spikes.iter().map(|&s| s == 1).collect();
            let got = layer.step(&in_spikes).expect("step").to_vec();
            let want = golden.step(&in_spikes);
            assert_eq!(got, want, "{}: batch {b}", kv.name);
            assert_eq!(
                layer.potentials().expect("potentials"),
                golden.potentials(),
                "{}: batch {b} V",
                kv.name
            );
        }
    }
}

/// L2/L3 cross-check: the full sentiment network on the macro simulator
/// must reproduce the Python integer model's V_out traces exactly.
#[test]
fn sentiment_network_matches_python_reference_traces() {
    require_artifacts!();
    let a = SentimentArtifacts::load(artifacts_dir()).expect("load sentiment artifacts");
    a.validate().expect("artifact validation");
    let mut net =
        SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).expect("build network");
    let n_ref = a.ref_vout_traces.len().min(16);
    for i in 0..n_ref {
        let r = net.run_review(&a.test_seqs[i]).expect("run review");
        let want: Vec<i64> = a.ref_vout_traces[i]
            .iter()
            .copied()
            .take(r.vout_trace.len())
            .collect();
        assert_eq!(
            r.vout_trace, want,
            "review {i}: macro-sim V_out trace diverges from Python reference"
        );
        assert_eq!(r.pred, a.ref_preds[i], "review {i} prediction");
    }
}

/// Accuracy: the macro-level evaluation must land on the manifest's
/// quantized accuracy (same data, same semantics → identical).
#[test]
fn sentiment_accuracy_matches_manifest() {
    require_artifacts!();
    let dir = artifacts_dir();
    let a = SentimentArtifacts::load(&dir).expect("load artifacts");
    let man = impulse::data::Manifest::read(dir.join("manifest.txt")).expect("manifest");
    let expect: f64 = man.get_f64("snn_sentiment_quant_acc").expect("acc key");

    let mut net =
        SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).expect("build network");
    let n = 250.min(a.test_seqs.len());
    let mut correct = 0usize;
    for i in 0..n {
        let r = net.run_review(&a.test_seqs[i]).expect("run");
        if r.pred == a.test_labels[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    // subset vs full-set: allow sampling slack
    assert!(
        (acc - expect).abs() < 0.08,
        "macro accuracy {acc:.4} vs manifest {expect:.4}"
    );
}

/// L3 runtime: the PJRT-executed AOT graph must match the macro
/// simulator exactly (same integers), proving all three layers compose.
#[test]
fn pjrt_runtime_matches_macro_simulation() {
    require_artifacts!();
    if !impulse::runtime::xla_available() {
        eprintln!("SKIP: built without the `xla` feature");
        return;
    }
    let dir = artifacts_dir();
    let a = SentimentArtifacts::load(&dir).expect("load artifacts");
    let rt = impulse::runtime::SentimentStepRuntime::load(
        &dir,
        a.w1.len(),
        a.w1[0].len(),
        a.w2[0].len(),
    )
    .expect("load + compile HLO");
    let mut net =
        SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).expect("build network");
    for i in 0..4.min(a.test_seqs.len()) {
        let (pred_xla, trace_xla) = rt
            .run_review(&a.emb_q, &a.test_seqs[i], 10)
            .expect("xla run");
        let r = net.run_review(&a.test_seqs[i]).expect("macro run");
        let trace_xla_i64: Vec<i64> = trace_xla.iter().map(|&v| v as i64).collect();
        assert_eq!(
            r.vout_trace, trace_xla_i64,
            "review {i}: macro vs XLA trace"
        );
        assert_eq!(r.pred, pred_xla, "review {i}: prediction");
    }
}

/// Sparsity: the network's measured overall sparsity should sit in the
/// paper's ~85% band (manifest cross-check with tolerance).
#[test]
fn sparsity_in_paper_band() {
    require_artifacts!();
    let dir = artifacts_dir();
    let a = SentimentArtifacts::load(&dir).expect("load artifacts");
    let mut net =
        SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).expect("build network");
    for i in 0..50.min(a.test_seqs.len()) {
        net.run_review(&a.test_seqs[i]).expect("run");
    }
    let overall = net.tracker.overall();
    assert!(
        overall > 0.75 && overall < 0.995,
        "overall sparsity {overall:.3} outside plausible band"
    );
}
