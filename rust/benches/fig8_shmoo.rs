//! Bench E3 (paper Fig 8): regenerate the Shmoo grid and verify the
//! published boundary points pass; times grid generation.

use impulse::bench_harness::Bencher;
use impulse::energy::{shmoo_boundary, ShmooModel, ShmooPath};

fn main() {
    println!("=== Fig 8: Shmoo (read/write vs CIM operating windows) ===\n");
    let m = ShmooModel::calibrated();
    print!("{}", m.standard_grid().render());
    println!("             VDD 0.6 → 1.2 V\n");

    println!("published CIM boundary vs model:");
    for (v, f) in shmoo_boundary() {
        let fm = m.fmax_hz(ShmooPath::Cim, v);
        println!(
            "  {v:.2} V: {:.1} MHz published, {:.1} MHz model ({}",
            f / 1e6,
            fm / 1e6,
            if m.passes(ShmooPath::Cim, v, f * 0.999) {
                "PASS)"
            } else {
                "FAIL)"
            }
        );
        assert!(m.passes(ShmooPath::Cim, v, f * 0.999));
    }
    println!("\nCIM window ⊂ read/write window:");
    for i in 0..7 {
        let v = 0.6 + 0.1 * i as f64;
        println!(
            "  {v:.1} V: R/W {:.0} MHz vs CIM {:.0} MHz",
            m.fmax_hz(ShmooPath::ReadWrite, v) / 1e6,
            m.fmax_hz(ShmooPath::Cim, v) / 1e6
        );
    }

    let mut b = Bencher::default();
    b.bench("shmoo grid generation (13×22 points)", 13 * 22, || {
        let g = m.standard_grid();
        std::hint::black_box(g.cells.len());
    });
}
