//! Perf bench: the in-band telemetry hot path. Recording must be
//! cheap enough to leave on unconditionally — these measurements are
//! the evidence (single atomic adds per event, a short CAS loop only
//! for the EDP accumulator) — and snapshot/exposition costs bound what
//! a scrape or `StatsRequest` does to a loaded server.

use impulse::bench_harness::Bencher;
use impulse::coordinator::{WorkloadInput, WorkloadKind};
use impulse::isa::InstructionKind;
use impulse::serve::encode_stats_response;
use impulse::telemetry::{Telemetry, TelemetryConfig, Transport};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    println!("=== telemetry hot-path overhead ===\n");
    let mut b = Bencher::default();
    let tele = Telemetry::new(TelemetryConfig::default());

    let batch = 1000u64;
    b.bench(&format!("record_submit (×{batch})"), batch, || {
        for _ in 0..batch {
            tele.record_submit(WorkloadKind::Sentiment);
        }
    });
    b.bench(&format!("record_response ok (×{batch})"), batch, || {
        for _ in 0..batch {
            tele.record_response(WorkloadKind::Sentiment, 35_200, 35_555, true);
        }
    });
    let words = WorkloadInput::Words((0..64).collect());
    b.bench(&format!("record_input 64 words (×{batch})"), batch, || {
        for _ in 0..batch {
            tele.record_input(&words);
        }
    });
    let image = WorkloadInput::Image { h: 28, w: 28, pixels: vec![0.5; 28 * 28] };
    b.bench(&format!("record_input 28×28 image (×{batch})"), batch, || {
        for _ in 0..batch {
            tele.record_input(&image);
        }
    });
    b.bench(&format!("record_wire tcp (×{batch})"), batch, || {
        for _ in 0..batch {
            tele.record_wire(Transport::Tcp, Duration::from_micros(181));
        }
    });
    let mut hist = BTreeMap::new();
    hist.insert(InstructionKind::AccW2V, 30_000u64);
    hist.insert(InstructionKind::SpikeCheck, 2_000u64);
    hist.insert(InstructionKind::ResetV, 2_000u64);
    b.bench(&format!("record_instr + energy_of (×{batch})"), batch, || {
        for _ in 0..batch {
            tele.record_instr(&hist);
            std::hint::black_box(tele.energy_of(&hist));
        }
    });

    // contended: 4 threads hammering one registry
    let shared = Arc::new(Telemetry::new(TelemetryConfig::default()));
    b.bench("4-thread contended record (×4000)", 4000, || {
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let t = Arc::clone(&shared);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        t.record_submit(WorkloadKind::Digits);
                        t.record_response(WorkloadKind::Digits, 51_234, 51_751, true);
                        t.record_wire(Transport::Tcp, Duration::from_micros(90));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    });

    // read-side costs
    b.bench("snapshot", 1, || {
        std::hint::black_box(tele.snapshot());
    });
    let snap = tele.snapshot();
    b.bench("encode_stats_response", 1, || {
        std::hint::black_box(encode_stats_response(&snap));
    });
    b.bench("to_prometheus", 1, || {
        std::hint::black_box(snap.to_prometheus());
    });

    let wire = encode_stats_response(&snap);
    println!("\nStatsResponse payload: {} bytes", wire.len());
    println!("Prometheus page: {} bytes", snap.to_prometheus().len());
}
