//! Bench E1 (paper Fig 6): energy per neuron update for IF / LIF / RMP,
//! measured by executing the actual instruction sequences on the macro
//! simulator and pricing them with the calibrated model. Also times the
//! simulator itself.

use impulse::bench_harness::{Bencher, Table};
use impulse::bitcell::Parity;
use impulse::energy::EnergyModel;
use impulse::isa::{neuron_sequence, NeuronType};
use impulse::macro_sim::{ImpulseMacro, MacroConfig};
use impulse::mapper::ConstRows;
use impulse::NOMINAL_VDD;

fn main() -> impulse::Result<()> {
    println!("=== Fig 6: neuron-update energy (paper: IF 1.81, LIF 2.67, RMP 1.68 pJ) ===\n");
    let e = EnergyModel::calibrated();
    let rows = ConstRows::default();

    let mut t = Table::new(&["neuron", "instrs/update", "energy/update (pJ)", "paper (pJ)"]);
    let paper = [("IF", 1.81), ("LIF", 2.67), ("RMP", 1.68)];
    for (neuron, (_, pub_pj)) in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP]
        .into_iter()
        .zip(paper)
    {
        // execute the sequence on a live macro and price its histogram
        let mut m = ImpulseMacro::new(MacroConfig::fast());
        m.write_v(0, Parity::Odd, &[10; 6])?;
        for r in 26..32 {
            let p = if r % 2 == 0 { Parity::Odd } else { Parity::Even };
            m.write_v(r, p, &[-3; 6])?;
        }
        m.reset_counters();
        for instr in neuron_sequence(neuron, 0, rows.for_parity(Parity::Odd), Parity::Odd) {
            m.execute(&instr)?;
        }
        let energy_pj = e.program_energy_j(&m.counts(), NOMINAL_VDD) * 1e12;
        t.row(&[
            neuron.name().into(),
            format!("{}", neuron.instructions_per_update()),
            format!("{energy_pj:.2}"),
            format!("{pub_pj:.2}"),
        ]);
    }
    println!("{}", t.render());

    println!("--- simulator timing (bit-level vs fast engine) ---");
    let mut b = Bencher::default();
    for (name, cfg) in [
        ("bit-level neuron update (RMP)", MacroConfig::bit_level()),
        ("fast-engine neuron update (RMP)", MacroConfig::fast()),
    ] {
        let mut m = ImpulseMacro::new(cfg);
        m.write_v(0, Parity::Odd, &[10; 6])?;
        m.write_v(28, Parity::Odd, &[-3; 6])?;
        m.write_v(30, Parity::Odd, &[0; 6])?;
        let seq = neuron_sequence(NeuronType::RMP, 0, rows.for_parity(Parity::Odd), Parity::Odd);
        b.bench(name, seq.len() as u64, || {
            for instr in &seq {
                m.execute(instr).unwrap();
            }
        });
    }
    Ok(())
}
