//! Bench E7 (paper Fig 11a): average spike sparsity per layer per
//! timestep, measured while running the trained network on the macro
//! pool.

use impulse::bench_harness::Table;
use impulse::data::{artifacts_available, artifacts_dir, SentimentArtifacts};
use impulse::macro_sim::MacroConfig;
use impulse::snn::SentimentNetwork;

fn main() -> impulse::Result<()> {
    println!("=== Fig 11a: spike sparsity per layer per timestep ===\n");
    if !artifacts_available() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    let n = 150.min(a.test_seqs.len());
    for i in 0..n {
        net.run_review(&a.test_seqs[i])?;
    }
    let table = net.tracker.table();
    let mut t = Table::new(&[
        "layer", "t1", "t2", "t3", "t4", "t5", "t6", "t7", "t8", "t9", "t10", "mean",
    ]);
    for (l, name) in ["input(enc)", "FC1", "FC2"].iter().enumerate() {
        let mut row: Vec<String> = vec![name.to_string()];
        for ts in 0..net.tracker.timesteps() {
            row.push(format!("{:.2}", table[l][ts]));
        }
        row.push(format!("{:.3}", net.tracker.layer_sparsity(l)));
        t.row(&row);
    }
    println!("{}", t.render());
    let overall = net.tracker.overall();
    println!("overall sparsity: {overall:.3} (paper: ~0.85 → drives the 97.4% EDP saving)");
    assert!(overall > 0.70, "sparsity collapsed: {overall}");
    println!("\nOK");
    Ok(())
}
