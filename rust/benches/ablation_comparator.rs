//! Ablation (modelling choice M3, DESIGN.md §5): SpikeCheck comparator
//! implementations.
//!
//! The paper's text describes the spike decision as "checking the COUT
//! from [the] MSB column peripheral" — an unsigned carry, which equals
//! the signed `V ≥ θ` only for non-negative V. This harness measures
//! how much that circuit-level choice matters at the *application*
//! level by evaluating the trained sentiment network under both modes.

use impulse::data::{artifacts_available, artifacts_dir, SentimentArtifacts};
use impulse::macro_sim::{ComparatorMode, MacroConfig};
use impulse::snn::SentimentNetwork;

fn main() -> impulse::Result<()> {
    println!("=== Ablation: SpikeCheck comparator (SignBit vs MsbCout) ===\n");
    if !artifacts_available() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let n = 300.min(a.test_seqs.len());

    let mut results = Vec::new();
    for (name, mode) in [
        ("SignBit (signed compare)", ComparatorMode::SignBit),
        ("MsbCout (literal circuit)", ComparatorMode::MsbCout),
    ] {
        let cfg = MacroConfig::fast().with_comparator(mode);
        let mut net = SentimentNetwork::from_artifacts(&a, cfg)?;
        let mut correct = 0usize;
        let mut spikes_total = 0u64;
        for i in 0..n {
            let r = net.run_review(&a.test_seqs[i])?;
            if r.pred == a.test_labels[i] {
                correct += 1;
            }
            spikes_total += r.cycles;
        }
        let acc = correct as f64 / n as f64;
        println!(
            "{name:<27} accuracy {acc:.4} ({correct}/{n}), {spikes_total} cycles"
        );
        results.push((name, acc));
    }
    let delta = results[0].1 - results[1].1;
    println!(
        "\naccuracy delta (SignBit − MsbCout): {delta:+.4}\n\
         interpretation: a pure unsigned carry-out fires every neuron whose V\n\
         is negative (unsigned wrap), causing spike storms (≈8× the cycles)\n\
         and chance-level accuracy. Since the silicon achieved 88.15%, the\n\
         paper's \"checking the COUT from [the] MSB column peripheral\" must\n\
         be shorthand for a sign-aware comparison — reproduction-level\n\
         evidence for modelling choice M3 (default: SignBit)."
    );
    // SignBit must stay in the paper's accuracy band; the literal-circuit
    // reading demonstrably cannot be what the silicon implements.
    assert!(results[0].1 > 0.7);
    assert!(results[0].1 > results[1].1 + 0.2);
    println!("\nOK");
    Ok(())
}
