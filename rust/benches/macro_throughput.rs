//! Perf bench (EXPERIMENTS.md §Perf): raw simulator throughput —
//! instructions/second for each engine, layer-step throughput,
//! end-to-end review latency on the worker pool, and the batched
//! serving engine (requests/sec and cycles/request per micro-batch
//! size). This is the L3 hot path the optimization pass iterates on.

use impulse::bench_harness::{Bencher, Table};
use impulse::bitcell::Parity;
use impulse::bits::XorShiftRng;
use impulse::coordinator::LayerPipeline;
use impulse::data::{artifacts_available, artifacts_dir, DigitsArtifacts, SentimentArtifacts};
use impulse::isa::{Instruction, InstructionKind};
use impulse::macro_sim::{ImpulseMacro, MacroConfig};
use impulse::snn::{DigitsNetwork, FcLayer, LayerParams, SentimentNetwork};

fn main() -> impulse::Result<()> {
    println!("=== macro simulator throughput (L3 hot path) ===\n");
    let mut b = Bencher::default();
    let mut rng = XorShiftRng::new(1);

    // raw AccW2V issue rate per engine
    for (name, cfg) in [
        ("AccW2V bit-level engine", MacroConfig::bit_level()),
        ("AccW2V fast engine", MacroConfig::fast()),
    ] {
        let mut m = ImpulseMacro::new(cfg);
        for r in 0..128 {
            let mut w = [0i64; 12];
            for x in w.iter_mut() {
                *x = rng.gen_i64(-32, 31);
            }
            m.write_weights(r, &w)?;
        }
        m.write_v(0, Parity::Odd, &[0; 6])?;
        let batch = 1000;
        b.bench(&format!("{name} (×{batch})"), batch, || {
            for i in 0..batch {
                m.execute(&Instruction::AccW2V {
                    w_row: (i % 128) as usize,
                    v_src: 0,
                    v_dst: 0,
                    parity: Parity::Odd,
                })
                .unwrap();
            }
        });
    }

    // full-layer timestep (128→128 = 11 tiles) at paper sparsity
    let weights: Vec<Vec<i64>> = (0..128)
        .map(|_| (0..128).map(|_| rng.gen_i64(-31, 31)).collect())
        .collect();
    let mut layer = FcLayer::new(&weights, LayerParams::rmp(150), MacroConfig::fast())?;
    let spikes: Vec<bool> = (0..128).map(|_| rng.gen_bool(0.15)).collect();
    let n_spk = spikes.iter().filter(|&&s| s).count() as u64;
    b.bench(
        &format!("128→128 layer timestep (fast, {n_spk} spikes)"),
        1,
        || {
            layer.step(&spikes).unwrap();
        },
    );

    // pipelined vs sequential 3-layer chain
    let dims = [128usize, 128, 128, 128];
    let mk_layers = |seed: u64| -> Vec<FcLayer> {
        let mut r = XorShiftRng::new(seed);
        dims.windows(2)
            .map(|d| {
                let w: Vec<Vec<i64>> = (0..d[0])
                    .map(|_| (0..d[1]).map(|_| r.gen_i64(-20, 20)).collect())
                    .collect();
                FcLayer::new(&w, LayerParams::rmp(150), MacroConfig::fast()).unwrap()
            })
            .collect()
    };
    let inputs: Vec<Vec<bool>> = (0..40)
        .map(|_| (0..128).map(|_| rng.gen_bool(0.15)).collect())
        .collect();
    let mut seq = LayerPipeline::new(mk_layers(7));
    b.bench("3-layer chain, sequential (40 steps)", 40, || {
        seq.reset_state().unwrap();
        seq.run_sequential(&inputs).unwrap();
    });
    let mut pipe = LayerPipeline::new(mk_layers(7));
    b.bench("3-layer chain, pipelined (40 steps)", 40, || {
        pipe.reset_state().unwrap();
        pipe.run_pipelined(&inputs, 4).unwrap();
    });

    // ------------------------------------------------------------------
    // Batched serving engine: requests/sec and cycles/request at micro-
    // batch sizes {1, 4, 16, 64}. Batch 1 is the sequential path; wider
    // batches fuse AccW2V issue across the union of spiking inputs.
    // ------------------------------------------------------------------
    println!("\n=== batched inference engine (reviews on the macro pool) ===\n");
    let a = if artifacts_available() {
        SentimentArtifacts::load(artifacts_dir())?
    } else {
        println!("(artifacts not built — benching on the synthetic bundle)\n");
        SentimentArtifacts::synthetic(2024)
    };
    let vocab = a.emb_q.len() as i64;
    let n_reqs = 64usize;
    let reviews: Vec<Vec<i64>> = (0..n_reqs)
        .map(|i| {
            if i < a.test_seqs.len() && !a.test_seqs[i].is_empty() {
                a.test_seqs[i].clone()
            } else {
                // deterministic filler sized like a short review
                (0..6).map(|j| ((i * 13 + j * 7) as i64) % vocab).collect()
            }
        })
        .collect();
    let refs: Vec<&[i64]> = reviews.iter().map(|r| r.as_slice()).collect();

    // sequential ground truth for the bit-identity check
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    let want: Vec<(u8, i64)> = refs
        .iter()
        .map(|r| net.run_review(r).map(|res| (res.pred, res.v_out)))
        .collect::<impulse::Result<_>>()?;

    // micro-batches wider than the V_MEM lane budget split into chunks
    // of at most `max_lanes` fused lanes (e.g. batch=16 → 13+3)
    let max_lanes = net.max_batch_lanes();
    println!("(fused lane budget: {max_lanes} lanes per chunk)\n");
    let mut table = Table::new(&[
        "batch", "lanes", "req/s", "cycles/req", "AccW2V/req", "identical",
    ]);
    let mut req_per_s = Vec::new();
    for &bsz in &[1usize, 4, 16, 64] {
        // cost accounting + bit-identity on one cold pass
        net.reset_counters();
        let mut preds = Vec::with_capacity(n_reqs);
        if bsz == 1 {
            for r in &refs {
                let res = net.run_review(r)?;
                preds.push((res.pred, res.v_out));
            }
        } else {
            for chunk in refs.chunks(bsz) {
                for res in net.run_reviews_batched(chunk)? {
                    preds.push((res.pred, res.v_out));
                }
            }
        }
        let identical = preds == want;
        let stats = net.stats();
        let cycles_per_req = stats.cycles as f64 / n_reqs as f64;
        let acc_per_req = stats
            .histogram
            .get(&InstructionKind::AccW2V)
            .copied()
            .unwrap_or(0) as f64
            / n_reqs as f64;

        // wall-clock requests/sec
        let r = b
            .bench(&format!("serve {n_reqs} reviews, batch={bsz}"), n_reqs as u64, || {
                if bsz == 1 {
                    for r in &refs {
                        net.run_review(r).unwrap();
                    }
                } else {
                    for chunk in refs.chunks(bsz) {
                        net.run_reviews_batched(chunk).unwrap();
                    }
                }
            })
            .clone();
        req_per_s.push((bsz, r.throughput_per_s));
        table.row(&[
            format!("{bsz}"),
            format!("{}", bsz.min(max_lanes)),
            format!("{:.1}", r.throughput_per_s),
            format!("{cycles_per_req:.0}"),
            format!("{acc_per_req:.0}"),
            if identical { "yes".into() } else { "NO".into() },
        ]);
        assert!(
            identical,
            "batch={bsz}: batched predictions diverge from the sequential path"
        );
    }
    println!("\n{}", table.render());
    let rps = |b: usize| {
        req_per_s
            .iter()
            .find(|&&(x, _)| x == b)
            .map(|&(_, v)| v)
            .unwrap_or(0.0)
    };
    println!(
        "derived: batch=16 vs batch=1 requests/sec speedup = {:.2}x",
        rps(16) / rps(1)
    );

    // ------------------------------------------------------------------
    // Batched digits (conv) inference: cycles/image and req/s at batch
    // {1, 4, 16} — the ISSUE 3 acceptance numbers. Batched cycles per
    // image must never exceed sequential (the union AccW2V stream can
    // only shrink the issue count).
    // ------------------------------------------------------------------
    println!("\n=== batched digits inference (conv fused lanes) ===\n");
    let da = if artifacts_available() {
        DigitsArtifacts::load(artifacts_dir())?
    } else {
        println!("(artifacts not built — benching on the synthetic digits bundle)\n");
        DigitsArtifacts::synthetic(2024)
    };
    let n_imgs = 16usize;
    let images: Vec<Vec<f32>> = (0..n_imgs)
        .map(|i| da.test_x[i % da.test_x.len()].clone())
        .collect();
    let img_refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    let mut dnet = DigitsNetwork::from_artifacts(&da, MacroConfig::fast())?;
    println!("(fused lane budget: {} lanes per chunk)\n", dnet.max_batch_lanes());
    let want: Vec<(u8, Vec<i64>)> = img_refs
        .iter()
        .map(|r| dnet.run_image(r).map(|res| (res.pred, res.v_out)))
        .collect::<impulse::Result<_>>()?;
    let mut dtable = Table::new(&["batch", "img/s", "cycles/img", "identical"]);
    let mut seq_cycles_per_img = f64::MAX;
    for &bsz in &[1usize, 4, 16] {
        dnet.reset_counters();
        let mut preds = Vec::with_capacity(n_imgs);
        if bsz == 1 {
            for r in &img_refs {
                let res = dnet.run_image(r)?;
                preds.push((res.pred, res.v_out));
            }
        } else {
            for chunk in img_refs.chunks(bsz) {
                for res in dnet.run_images_batched(chunk)? {
                    preds.push((res.pred, res.v_out));
                }
            }
        }
        let identical = preds == want;
        let cycles_per_img = dnet.stats().cycles as f64 / n_imgs as f64;
        if bsz == 1 {
            seq_cycles_per_img = cycles_per_img;
        }
        let r = b
            .bench(&format!("serve {n_imgs} digit images, batch={bsz}"), n_imgs as u64, || {
                if bsz == 1 {
                    for r in &img_refs {
                        dnet.run_image(r).unwrap();
                    }
                } else {
                    for chunk in img_refs.chunks(bsz) {
                        dnet.run_images_batched(chunk).unwrap();
                    }
                }
            })
            .clone();
        dtable.row(&[
            format!("{bsz}"),
            format!("{:.1}", r.throughput_per_s),
            format!("{cycles_per_img:.0}"),
            if identical { "yes".into() } else { "NO".into() },
        ]);
        assert!(
            identical,
            "batch={bsz}: batched digits predictions diverge from run_image"
        );
        assert!(
            cycles_per_img <= seq_cycles_per_img + 0.5,
            "batch={bsz}: {cycles_per_img:.0} cycles/img exceeds sequential \
             {seq_cycles_per_img:.0}"
        );
    }
    println!("\n{}", dtable.render());
    println!("derived: fast-engine instruction rate = see above; target ≥1e7 instr/s");
    Ok(())
}
