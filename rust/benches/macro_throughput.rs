//! Perf bench (EXPERIMENTS.md §Perf): raw simulator throughput —
//! instructions/second for each engine, layer-step throughput, and
//! end-to-end review latency on the worker pool. This is the L3 hot
//! path the optimization pass iterates on.

use impulse::bench_harness::Bencher;
use impulse::bitcell::Parity;
use impulse::bits::XorShiftRng;
use impulse::coordinator::LayerPipeline;
use impulse::isa::Instruction;
use impulse::macro_sim::{ImpulseMacro, MacroConfig};
use impulse::snn::{FcLayer, LayerParams};

fn main() -> impulse::Result<()> {
    println!("=== macro simulator throughput (L3 hot path) ===\n");
    let mut b = Bencher::default();
    let mut rng = XorShiftRng::new(1);

    // raw AccW2V issue rate per engine
    for (name, cfg) in [
        ("AccW2V bit-level engine", MacroConfig::bit_level()),
        ("AccW2V fast engine", MacroConfig::fast()),
    ] {
        let mut m = ImpulseMacro::new(cfg);
        for r in 0..128 {
            let mut w = [0i64; 12];
            for x in w.iter_mut() {
                *x = rng.gen_i64(-32, 31);
            }
            m.write_weights(r, &w)?;
        }
        m.write_v(0, Parity::Odd, &[0; 6])?;
        let batch = 1000;
        b.bench(&format!("{name} (×{batch})"), batch, || {
            for i in 0..batch {
                m.execute(&Instruction::AccW2V {
                    w_row: (i % 128) as usize,
                    v_src: 0,
                    v_dst: 0,
                    parity: Parity::Odd,
                })
                .unwrap();
            }
        });
    }

    // full-layer timestep (128→128 = 11 tiles) at paper sparsity
    let weights: Vec<Vec<i64>> = (0..128)
        .map(|_| (0..128).map(|_| rng.gen_i64(-31, 31)).collect())
        .collect();
    let mut layer = FcLayer::new(&weights, LayerParams::rmp(150), MacroConfig::fast())?;
    let spikes: Vec<bool> = (0..128).map(|_| rng.gen_bool(0.15)).collect();
    let n_spk = spikes.iter().filter(|&&s| s).count() as u64;
    b.bench(
        &format!("128→128 layer timestep (fast, {n_spk} spikes)"),
        1,
        || {
            layer.step(&spikes).unwrap();
        },
    );

    // pipelined vs sequential 3-layer chain
    let dims = [128usize, 128, 128, 128];
    let mk_layers = |seed: u64| -> Vec<FcLayer> {
        let mut r = XorShiftRng::new(seed);
        dims.windows(2)
            .map(|d| {
                let w: Vec<Vec<i64>> = (0..d[0])
                    .map(|_| (0..d[1]).map(|_| r.gen_i64(-20, 20)).collect())
                    .collect();
                FcLayer::new(&w, LayerParams::rmp(150), MacroConfig::fast()).unwrap()
            })
            .collect()
    };
    let inputs: Vec<Vec<bool>> = (0..40)
        .map(|_| (0..128).map(|_| rng.gen_bool(0.15)).collect())
        .collect();
    let mut seq = LayerPipeline::new(mk_layers(7));
    b.bench("3-layer chain, sequential (40 steps)", 40, || {
        seq.reset_state().unwrap();
        seq.run_sequential(&inputs).unwrap();
    });
    let mut pipe = LayerPipeline::new(mk_layers(7));
    b.bench("3-layer chain, pipelined (40 steps)", 40, || {
        pipe.reset_state().unwrap();
        pipe.run_pipelined(&inputs, 4).unwrap();
    });

    println!("\nderived: fast-engine instruction rate = see above; target ≥1e7 instr/s");
    Ok(())
}
