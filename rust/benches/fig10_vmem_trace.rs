//! Bench E6 (paper Fig 10): the output neuron's membrane-potential
//! trajectory over per-word timesteps — positive reviews drift
//! positive, negative reviews negative; checks sign/label agreement
//! statistics across a subset.

use impulse::data::{artifacts_available, artifacts_dir, SentimentArtifacts};
use impulse::macro_sim::MacroConfig;
use impulse::snn::SentimentNetwork;

fn main() -> impulse::Result<()> {
    println!("=== Fig 10: output-neuron V_MEM trajectories ===\n");
    if !artifacts_available() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let a = SentimentArtifacts::load(artifacts_dir())?;
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;

    // exemplary traces, one per class
    for label in [1u8, 0u8] {
        let idx = (0..a.test_seqs.len())
            .find(|&i| a.test_labels[i] == label)
            .unwrap();
        let r = net.run_review(&a.test_seqs[idx])?;
        println!(
            "{} review #{idx}: V_out after each word:",
            if label == 1 { "positive" } else { "negative" }
        );
        print!("  ");
        for v in &r.vout_trace {
            print!("{v:>6} ");
        }
        println!();
        let max = r.vout_trace.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
        for &v in &r.vout_trace {
            let w = ((v.abs() as f64 / max as f64) * 28.0) as usize;
            if v >= 0 {
                println!("  {:>29}|{}", "", "#".repeat(w));
            } else {
                println!("  {:>width$}{}|", "", "#".repeat(w), width = 29 - w);
            }
        }
        println!();
    }

    // statistics: final-V sign should track the label (that IS the
    // classifier); also report how often the sign settles early.
    let n = 200.min(a.test_seqs.len());
    let mut agree = 0usize;
    let mut early_settle = 0usize;
    for i in 0..n {
        let r = net.run_review(&a.test_seqs[i])?;
        let want_pos = a.test_labels[i] == 1;
        if (r.v_out >= 0) == want_pos {
            agree += 1;
        }
        let half = r.vout_trace.len() / 2;
        if !r.vout_trace.is_empty()
            && r.vout_trace[half..].iter().all(|&v| (v >= 0) == (r.v_out >= 0))
        {
            early_settle += 1;
        }
    }
    println!("final-V sign matches label: {}/{n} ({:.3})", agree, agree as f64 / n as f64);
    println!(
        "sign stable over second half of review: {}/{n} ({:.3}) — V_MEM accumulates evidence",
        early_settle,
        early_settle as f64 / n as f64
    );
    println!("\nOK");
    Ok(())
}
