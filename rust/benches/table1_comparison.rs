//! Bench E9 (paper Table I): the cross-macro comparison — published
//! competitor numbers + our computed "This Work" columns; asserts the
//! published ratios hold.

use impulse::baselines::table1_rows;
use impulse::bench_harness::Table;
use impulse::energy::{AreaModel, EnergyModel};

fn main() {
    println!("=== Table I: comparison with other SNN and CIM macros ===\n");
    let rows = table1_rows(&EnergyModel::calibrated(), &AreaModel::calibrated());
    let mut t = Table::new(&[
        "macro", "tech", "type", "precision", "cell", "flex", "sparse",
        "mm²", "V", "MHz", "mW", "GOPS/mm²", "TOPS/W",
    ]);
    for r in &rows {
        t.row(&[
            r.name.into(),
            format!("{}", r.technology_nm),
            r.macro_type.into(),
            r.precision.into(),
            r.bitcell.into(),
            if r.flexible_neuron { "Y" } else { "N" }.into(),
            if r.sparsity_support { "Y" } else { "N" }.into(),
            r.area_mm2.map(|a| format!("{a:.4}")).unwrap_or("-".into()),
            format!("{:.2}", r.supply_v),
            format!("{:.2}", r.freq_mhz),
            r.power_mw.map(|p| format!("{p:.3}")).unwrap_or("-".into()),
            r.gops_per_mm2.map(|g| format!("{g:.2}")).unwrap_or("-".into()),
            r.tops_per_w.map(|x| format!("{x:.3}")).unwrap_or("-".into()),
        ]);
    }
    println!("{}", t.render());

    // §III's ratio claims vs [13] (1.5×) and [14] (2.2×, 16b→11b scaled)
    let ours = rows
        .iter()
        .find(|r| r.name == "This Work (0.85V)")
        .unwrap()
        .tops_per_w
        .unwrap();
    let isscc = rows.iter().find(|r| r.name.contains("[13]")).unwrap().tops_per_w.unwrap();
    let vlsi20 = rows.iter().find(|r| r.name.contains("[14]")).unwrap().tops_per_w.unwrap();
    // linear bit-precision scaling of [14] 16b→11b as the paper does
    let vlsi20_11b = vlsi20 * 16.0 / 11.0;
    println!("efficiency ratios at point D:");
    println!(
        "  vs ISSCC'19 [13] (8b, scaled): {:.2}× (paper ~1.5×... both scaled)",
        ours / isscc
    );
    println!("  vs VLSI'20 [14] (11b-scaled): {:.2}× (paper 2.2×)", ours / vlsi20_11b);
    assert!(ours > isscc && ours > vlsi20_11b);
    println!("\nOK");
}
