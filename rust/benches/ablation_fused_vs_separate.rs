//! Ablation (paper Fig 2's motivation, DESIGN.md design-choice A1):
//! fused W/V CIM macro vs the separate-SRAM strawman accelerator, over
//! sparsity — the architectural reason the macro exists.

use impulse::baselines::VanillaAccelModel;
use impulse::bench_harness::Table;
use impulse::energy::EnergyModel;
use impulse::isa::NeuronType;
use impulse::NOMINAL_VDD;

fn main() {
    println!("=== Ablation: fused CIM vs separate W/V SRAMs (Fig 2 strawman) ===\n");
    let e = EnergyModel::calibrated();
    let v = VanillaAccelModel::new(&e);

    let mut t = Table::new(&[
        "sparsity", "separate (pJ/step)", "fused (pJ/step)", "energy ratio", "cycle ratio",
    ]);
    for pct in (0..=100).step_by(10) {
        let s = pct as f64 / 100.0;
        let van = v.timestep_energy_j(s, NeuronType::RMP, NOMINAL_VDD) * 1e12;
        let imp = v.impulse_timestep_energy_j(s, NeuronType::RMP, NOMINAL_VDD) * 1e12;
        let events = 2.0 * (1.0 - s) * 128.0;
        let cyc_ratio = if events > 0.0 {
            (events * v.accumulate_cycles() as f64 + 4.0 * 3.0)
                / (events + 4.0)
        } else {
            3.0
        };
        t.row(&[
            format!("{s:.1}"),
            format!("{van:.2}"),
            format!("{imp:.2}"),
            format!("{:.2}×", van / imp),
            format!("{cyc_ratio:.2}×"),
        ]);
    }
    println!("{}", t.render());

    println!("per-neuron-type energy ratio at 85% sparsity:");
    for n in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
        println!("  {:<4} {:.2}×", n.name(), v.energy_ratio(0.85, n, NOMINAL_VDD));
    }
    println!("\nfused wins at every sparsity; the gap widens with spike traffic — the");
    println!("paper's motivation for fusing V_MEM into the weight array.\nOK");
}
