//! Bench E4/E10 (paper Fig 9a): power and TOPS/W for AccW2V across the
//! operating points A–G, plus per-instruction efficiencies at point D.
//! Asserts the paper's headline 0.99 TOPS/W and the ordering.

use impulse::bench_harness::Table;
use impulse::energy::{EnergyModel, OPERATING_POINTS};
use impulse::isa::InstructionKind;
use impulse::metrics::eng;
use impulse::{NOMINAL_FREQ_HZ, NOMINAL_VDD};

fn main() {
    println!("=== Fig 9a: AccW2V power & energy-efficiency (points A–G) ===\n");
    let e = EnergyModel::calibrated();
    let mut t = Table::new(&["pt", "V", "MHz", "power (model)", "power (meas.)", "TOPS/W"]);
    let mut best_measured = ("", 0.0f64);
    for p in OPERATING_POINTS {
        let pw = e.avg_power_w(p.vdd, p.freq_hz);
        let eff = e.tops_per_w(InstructionKind::AccW2V, p.vdd, p.freq_hz);
        if p.measured_power_w.is_some() && eff > best_measured.1 {
            best_measured = (p.label, eff);
        }
        t.row(&[
            p.label.into(),
            format!("{:.2}", p.vdd),
            format!("{:.2}", p.freq_hz / 1e6),
            eng(pw, "W"),
            p.measured_power_w.map(|w| eng(w, "W")).unwrap_or("-".into()),
            format!("{eff:.3}"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "optimal measured point: {} ({:.3} TOPS/W) — paper: D (0.99 TOPS/W)",
        best_measured.0, best_measured.1
    );
    println!("(B/C/E/F are model interpolations at assumed (V,f); the fit's");
    println!(" optimum band is 0.75–0.90 V, consistent with D being the silicon optimum)");
    assert_eq!(
        best_measured.0, "D",
        "efficiency must peak at point D among measured points"
    );
    assert!((best_measured.1 - 0.99).abs() < 0.12);

    println!("\nper-instruction TOPS/W at point D (paper: 0.99/1.18/1.02/1.22):");
    let published = [
        (InstructionKind::AccW2V, 0.99),
        (InstructionKind::AccV2V, 1.18),
        (InstructionKind::ResetV, 1.02),
        (InstructionKind::SpikeCheck, 1.22),
    ];
    for (k, pub_eff) in published {
        let eff = e.tops_per_w(k, NOMINAL_VDD, NOMINAL_FREQ_HZ);
        println!("  {:<11} {eff:.3}  (paper {pub_eff:.2})", k.name());
        assert!((eff - pub_eff).abs() / pub_eff < 0.12, "{k:?}");
    }
    println!("\nOK");
}
