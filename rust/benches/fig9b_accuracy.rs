//! Bench E5 (paper Fig 9b): sentiment accuracy vs the LSTM baseline
//! (accuracy within ~1 %, parameters 8.5× apart) and digits accuracy,
//! measured on the macro simulator. Uses a test subset to keep bench
//! runtime bounded; the examples run the full sets.

use impulse::baselines::Lstm;
use impulse::bench_harness::Table;
use impulse::data::{artifacts_available, artifacts_dir, Manifest, SentimentArtifacts};
use impulse::macro_sim::MacroConfig;
use impulse::snn::SentimentNetwork;

fn main() -> impulse::Result<()> {
    println!("=== Fig 9b: accuracy & parameter comparison ===\n");
    if !artifacts_available() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let dir = artifacts_dir();
    let a = SentimentArtifacts::load(&dir)?;
    let man = Manifest::read(dir.join("manifest.txt"))?;

    let n = 300.min(a.test_seqs.len());
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    let mut correct = 0usize;
    for i in 0..n {
        if net.run_review(&a.test_seqs[i])?.pred == a.test_labels[i] {
            correct += 1;
        }
    }
    let snn_acc = correct as f64 / n as f64;

    // LSTM baseline inference in Rust over the same subset would need
    // the float embeddings; the trained weights + accuracy come from
    // the manifest (full test set), and the Rust LSTM implementation is
    // cross-checked in its own tests.
    let lstm = Lstm::load(&dir)?;
    let lstm_params = lstm.num_params();
    let snn_params: usize = man.get_i64("snn_sentiment_params").unwrap_or(0) as usize;

    let mut t = Table::new(&["model", "params", "accuracy", "notes"]);
    t.row(&[
        "SNN on IMPULSE pool".into(),
        format!("{snn_params}"),
        format!("{snn_acc:.4}"),
        format!("{n}-review subset, macro simulator"),
    ]);
    t.row(&[
        "SNN (python int ref)".into(),
        format!("{snn_params}"),
        man.get("snn_sentiment_quant_acc").unwrap_or("?").into(),
        "full test set".into(),
    ]);
    t.row(&[
        "2-layer LSTM".into(),
        format!("{lstm_params}"),
        man.get("lstm_acc").unwrap_or("?").into(),
        "full test set".into(),
    ]);
    t.row(&[
        "digits SNN (LeNet-5 mod)".into(),
        man.get("snn_digits_params").unwrap_or("?").into(),
        man.get("snn_digits_quant_acc").unwrap_or("?").into(),
        "paper MNIST: 0.9896".into(),
    ]);
    println!("{}", t.render());

    let ratio = lstm_params as f64 / snn_params as f64;
    println!("parameter ratio LSTM/SNN: {ratio:.2}× (paper: 8.5×)");
    assert!((ratio - 8.46).abs() < 0.2, "parameter ratio shifted: {ratio}");
    let lstm_acc = man.get_f64("lstm_acc").unwrap_or(1.0);
    println!(
        "accuracy gap (LSTM − SNN): {:.3} (paper: ~0.01 with 8.5× fewer params)",
        lstm_acc - snn_acc
    );
    println!("\nOK");
    Ok(())
}
