//! Bench E8 (paper Fig 11b): EDP per neuron per timestep vs input
//! sparsity — analytic model cross-checked against instruction counts
//! measured on the simulator; asserts the 97.4 % headline.

use impulse::bench_harness::{Bencher, Table};
use impulse::energy::{edp_per_neuron_timestep, EnergyModel, SparsitySweep};
use impulse::isa::NeuronType;
use impulse::macro_sim::MacroConfig;
use impulse::snn::{FcLayer, LayerParams};
use impulse::{NOMINAL_FREQ_HZ, NOMINAL_VDD};

fn main() -> impulse::Result<()> {
    println!("=== Fig 11b: EDP vs sparsity (RMP, point D) ===\n");
    let e = EnergyModel::calibrated();
    let weights: Vec<Vec<i64>> = (0..128)
        .map(|i| (0..12).map(|j| ((i * 5 + j) % 63) as i64 - 31).collect())
        .collect();

    let mut t = Table::new(&["sparsity", "EDP model", "EDP measured", "reduction"]);
    let base = edp_per_neuron_timestep(&e, 0.0, NeuronType::RMP, NOMINAL_VDD, NOMINAL_FREQ_HZ);
    for pct in (0..=100).step_by(10) {
        let s = pct as f64 / 100.0;
        let model = edp_per_neuron_timestep(&e, s, NeuronType::RMP, NOMINAL_VDD, NOMINAL_FREQ_HZ);
        let mut layer = FcLayer::new(&weights, LayerParams::rmp(200), MacroConfig::fast())?;
        let n_spikes = ((1.0 - s) * 128.0).round() as usize;
        let mut spikes = vec![false; 128];
        for sp in spikes.iter_mut().take(n_spikes) {
            *sp = true;
        }
        layer.step(&spikes)?;
        let st = layer.stats();
        let measured = (e.program_energy_j(&st.histogram, NOMINAL_VDD) / 12.0)
            * (e.delay_s(st.cycles, NOMINAL_FREQ_HZ) / 12.0);
        let rel = (measured - model.edp).abs() / model.edp;
        assert!(rel < 0.02, "model vs measured diverge at s={s}: {rel}");
        t.row(&[
            format!("{s:.1}"),
            format!("{:.3e}", model.edp),
            format!("{measured:.3e}"),
            format!("-{:.1}%", 100.0 * (1.0 - model.edp / base.edp)),
        ]);
    }
    println!("{}", t.render());

    let sweep = SparsitySweep::run(&e, NeuronType::RMP, 100);
    let headline = sweep.reduction_at(0.85);
    println!("EDP reduction at 85% sparsity: {:.1}% (paper: 97.4%)", 100.0 * headline);
    assert!((headline - 0.974).abs() < 0.005);

    println!("\n--- timing: one timestep at 85% vs 0% sparsity ---");
    let mut b = Bencher::default();
    for (name, s) in [("timestep @ 85% sparsity", 0.85f64), ("timestep @ 0% sparsity", 0.0f64)] {
        let mut layer = FcLayer::new(&weights, LayerParams::rmp(200), MacroConfig::fast())?;
        let n_spikes = ((1.0 - s) * 128.0).round() as usize;
        let mut spikes = vec![false; 128];
        for sp in spikes.iter_mut().take(n_spikes) {
            *sp = true;
        }
        b.bench(name, 1, || {
            layer.step(&spikes).unwrap();
        });
    }
    println!("\nOK");
    Ok(())
}
