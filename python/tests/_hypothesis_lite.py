"""A tiny deterministic stand-in for the `hypothesis` API surface used
by the kernel tests, so property sweeps still run (with fixed seeds and
fewer examples) when hypothesis is not installed in the offline image.

Supported: @settings(max_examples=…, deadline=…), @given(**strategies),
strategies.integers / floats / sampled_from. Each @given test runs
`max_examples` cases drawn from a seeded PRNG — deterministic across
runs, so failures are reproducible.
"""

import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 - mirrors `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._lite_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # No functools.wraps: pytest must see a zero-argument signature,
        # not the strategy parameters (it would treat them as fixtures).
        def wrapper():
            n = getattr(wrapper, "_lite_max_examples", 20)
            seed = sum(ord(c) for c in fn.__name__) ^ 0xC0FFEE
            rng = random.Random(seed)
            for case in range(n):
                kwargs = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(**kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"case {case}: {kwargs!r}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
