"""Synthetic dataset generators: determinism, structure, learnability
signal."""

import numpy as np

from compile import datasets


def test_sentiment_deterministic():
    a = datasets.make_sentiment(vocab_size=200, n_train=50, n_test=20, seed=3)
    b = datasets.make_sentiment(vocab_size=200, n_train=50, n_test=20, seed=3)
    np.testing.assert_array_equal(a.embeddings, b.embeddings)
    np.testing.assert_array_equal(a.train_labels, b.train_labels)
    for s1, s2 in zip(a.train_seqs, b.train_seqs):
        np.testing.assert_array_equal(s1, s2)


def test_sentiment_structure():
    d = datasets.make_sentiment(vocab_size=300, n_train=100, n_test=40, seed=1)
    assert d.embeddings.shape == (300, datasets.EMB_DIM)
    assert d.embeddings.dtype == np.float32
    assert set(np.unique(d.polarity)) <= {-1, 0, 1}
    assert len(d.train_seqs) == 100 and len(d.test_seqs) == 40
    assert all(5 <= len(s) <= 15 for s in d.train_seqs)
    assert all(s.max() < 300 and s.min() >= 0 for s in d.train_seqs)
    # both classes present
    assert 0 < d.train_labels.mean() < 1


def test_sentiment_has_planted_signal():
    # A trivial polarity-sum classifier must beat chance comfortably —
    # the corpus carries the sequential-evidence signal the SNN needs.
    d = datasets.make_sentiment(vocab_size=500, n_train=400, n_test=100, seed=5)
    correct = 0
    for seq, label in zip(d.test_seqs, d.test_labels):
        pred = 1 if d.polarity[seq].sum() >= 0 else 0
        correct += pred == label
    assert correct / len(d.test_seqs) > 0.9


def test_digits_deterministic_and_shaped():
    a = datasets.make_digits(n_train=20, n_test=10, seed=2)
    b = datasets.make_digits(n_train=20, n_test=10, seed=2)
    np.testing.assert_array_equal(a.train_x, b.train_x)
    assert a.train_x.shape == (20, 28, 28)
    assert a.train_x.min() >= 0.0 and a.train_x.max() <= 1.0
    assert set(np.unique(a.train_y)) <= set(range(10))


def test_digits_classes_look_different():
    d = datasets.make_digits(n_train=200, n_test=1, seed=4)
    # mean image per class should differ appreciably between digits
    means = {}
    for c in range(10):
        xs = d.train_x[d.train_y == c]
        if len(xs):
            means[c] = xs.mean(axis=0)
    keys = list(means)
    diffs = [
        np.abs(means[a] - means[b]).mean()
        for i, a in enumerate(keys)
        for b in keys[i + 1 :]
    ]
    assert np.mean(diffs) > 0.02


def test_pad_sequences():
    seqs = [np.array([1, 2, 3], dtype=np.int32), np.array([7], dtype=np.int32)]
    out, lens = datasets.pad_sequences(seqs, 5)
    np.testing.assert_array_equal(out[0], [1, 2, 3, -1, -1])
    np.testing.assert_array_equal(out[1], [7, -1, -1, -1, -1])
    np.testing.assert_array_equal(lens, [3, 1])
    # truncation
    out2, lens2 = datasets.pad_sequences(seqs, 2)
    np.testing.assert_array_equal(out2[0], [1, 2])
    assert lens2[0] == 2
