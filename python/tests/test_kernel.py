"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, modes, and value ranges; every case must match
the reference bit-exactly (integer semantics, no tolerance).
"""

import numpy as np
import pytest

try:  # hypothesis is optional in the offline CI image
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in offline CI
    from _hypothesis_lite import given, settings, strategies as st

    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.snn_step import encoder_step, snn_step


def _rand_case(rng, b, m, n, p_spike):
    spikes = (rng.random((b, m)) < p_spike).astype(np.int32)
    weights = rng.integers(-32, 32, size=(m, n)).astype(np.int32)
    v = rng.integers(-1024, 1024, size=(b, n)).astype(np.int32)
    return spikes, weights, v


@pytest.mark.parametrize("mode", [ref.IF, ref.LIF, ref.RMP])
def test_kernel_matches_ref_basic(mode):
    rng = np.random.default_rng(0)
    spikes, weights, v = _rand_case(rng, 4, 100, 128, 0.15)
    thr, leak = 200, 3
    v_ref, s_ref = ref.snn_step_ref(
        jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v),
        thr, mode=mode, leak=leak,
    )
    v_k, s_k = snn_step(
        jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v),
        thr, mode=mode, leak=leak,
    )
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    m=st.integers(1, 130),
    n=st.integers(1, 130),
    p=st.floats(0.0, 1.0),
    thr=st.integers(1, 1023),
    mode=st.sampled_from([ref.IF, ref.LIF, ref.RMP]),
    leak=st.integers(0, 16),
    seed=st.integers(0, 2**31 - 1),
    block_b=st.sampled_from([1, 3, 8]),
    block_n=st.sampled_from([16, 64, 128]),
)
def test_kernel_matches_ref_swept(b, m, n, p, thr, mode, leak, seed, block_b, block_n):
    rng = np.random.default_rng(seed)
    spikes, weights, v = _rand_case(rng, b, m, n, p)
    v_ref, s_ref = ref.snn_step_ref(
        jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v),
        thr, mode=mode, leak=leak,
    )
    v_k, s_k = snn_step(
        jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v),
        thr, mode=mode, leak=leak, block_b=block_b, block_n=block_n,
    )
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_wrap11_semantics():
    x = jnp.asarray([1023, 1024, -1024, -1025, 2047, 2048, 0], jnp.int32)
    got = np.asarray(ref.wrap11(x))
    np.testing.assert_array_equal(got, [1023, -1024, -1024, 1023, -1, 0, 0])


def test_spike_includes_wraparound_artifact():
    # V = -1000, θ = 50: V − θ wraps positive ⇒ hardware spikes.
    v = jnp.asarray([[-1000]], jnp.int32)
    s = ref.spike_of(v, 50)
    assert int(s[0, 0]) == 1


def test_rmp_retains_residual():
    spikes = jnp.zeros((1, 4), jnp.int32)
    w = jnp.zeros((4, 1), jnp.int32)
    v = jnp.asarray([[27]], jnp.int32)
    v2, s = ref.snn_step_ref(spikes, w, v, 10, mode=ref.RMP)
    assert int(s[0, 0]) == 1 and int(v2[0, 0]) == 17


def test_if_hard_reset():
    spikes = jnp.zeros((1, 4), jnp.int32)
    w = jnp.zeros((4, 1), jnp.int32)
    v = jnp.asarray([[27]], jnp.int32)
    v2, s = ref.snn_step_ref(spikes, w, v, 10, mode=ref.IF)
    assert int(s[0, 0]) == 1 and int(v2[0, 0]) == 0


def test_lif_leak_applied_before_check():
    spikes = jnp.zeros((1, 1), jnp.int32)
    w = jnp.zeros((1, 1), jnp.int32)
    v = jnp.asarray([[10]], jnp.int32)
    v2, s = ref.snn_step_ref(spikes, w, v, 10, mode=ref.LIF, leak=1)
    # 10 − 1 = 9 < 10 ⇒ no spike
    assert int(s[0, 0]) == 0 and int(v2[0, 0]) == 9


def test_zero_spikes_only_neuron_dynamics():
    rng = np.random.default_rng(3)
    _, weights, v = _rand_case(rng, 2, 50, 30, 0.0)
    spikes = np.zeros((2, 50), np.int32)
    v_ref, s_ref = ref.snn_step_ref(
        jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v), 100
    )
    v_k, s_k = snn_step(
        jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v), 100
    )
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    m=st.integers(1, 120),
    thr=st.integers(1, 5000),
    seed=st.integers(0, 2**31 - 1),
)
def test_encoder_matches_ref(b, m, thr, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-1024, 1024, size=(b, m)).astype(np.int32)
    v = rng.integers(-4096, 4096, size=(b, m)).astype(np.int32)
    v_ref, s_ref = ref.encoder_step_ref(jnp.asarray(x), jnp.asarray(v), thr)
    v_k, s_k = encoder_step(jnp.asarray(x), jnp.asarray(v), thr)
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))
