"""Model-level tests: parameter counts, float/int consistency, training
smoke, quantization behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, lstm_baseline, model, quantize, snn_train


def test_sentiment_param_count_matches_paper():
    params = model.init_sentiment_params(jax.random.PRNGKey(0))
    # 100·128 + 128·128 + 128 + 3 thresholds = 29,315 ≈ the paper's 29.3K
    assert model.count_sentiment_params(params) == 29315


def test_lstm_param_count_matches_paper():
    params = lstm_baseline.init_lstm_params(jax.random.PRNGKey(0))
    # 4(100·128+128²) + 4(2·128²) + 128 = 247,936 ≈ the paper's 247.8K
    assert lstm_baseline.count_lstm_params(params) == 247936
    snn = 29315
    assert abs(247936 / snn - 8.46) < 0.02  # the 8.5× headline


def test_float_forward_shapes_and_masking():
    params = model.init_sentiment_params(jax.random.PRNGKey(1))
    emb = np.random.default_rng(0).normal(size=(4, 6, 100)).astype(np.float32)
    mask = np.ones((4, 6), np.float32)
    mask[2, 3:] = 0.0
    v_out, aux = model.sentiment_forward_float(params, jnp.asarray(emb), jnp.asarray(mask))
    assert v_out.shape == (4,)
    assert aux["v_out_trace"].shape == (4, 6)
    # masked sample's output is frozen after its last real word
    tr = np.asarray(aux["v_out_trace"])
    assert tr[2, 3] == tr[2, 4] == tr[2, 5]


def test_training_reduces_loss_quickly():
    data = datasets.make_sentiment(vocab_size=300, n_train=300, n_test=100, seed=9)
    params, hist = snn_train.train_sentiment(data, epochs=3, batch=50, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]
    acc = snn_train.eval_sentiment_float(params, data)
    assert acc > 0.6  # well above chance after 2 epochs


def test_quantized_matches_float_predictions_mostly():
    data = datasets.make_sentiment(vocab_size=300, n_train=400, n_test=100, seed=10)
    params, _ = snn_train.train_sentiment(data, epochs=2, batch=50, log=lambda *_: None)
    seqs, lens = datasets.pad_sequences(data.test_seqs, 15)
    emb_seq = data.embeddings[np.clip(seqs, 0, None)]
    mask = (seqs >= 0).astype(np.float32)
    v_f, aux = jax.jit(model.sentiment_forward_float)(
        params, jnp.asarray(emb_seq), jnp.asarray(mask)
    )
    cal = [float(x) for x in np.asarray(aux["v_extremes"])]
    q = quantize.quantize_sentiment(params, data, v_extremes=cal)
    preds, traces, _ = model.sentiment_infer_int(q, seqs, lens)
    float_preds = (np.asarray(v_f) >= 0).astype(np.uint8)
    agreement = (preds == float_preds).mean()
    assert agreement > 0.8, f"quantized/float agreement {agreement}"


def test_quantized_weights_fit_hardware_format():
    data = datasets.make_sentiment(vocab_size=200, n_train=60, n_test=20, seed=11)
    params = model.init_sentiment_params(jax.random.PRNGKey(3))
    q = quantize.quantize_sentiment(params, data, v_extremes=[20.0, 20.0, 10.0])
    for w in (q.w1, q.w2, q.w_out):
        assert w.min() >= -32 and w.max() <= 31
    assert 1 <= q.thr1 <= 1023 and 1 <= q.thr2 <= 1023
    assert q.thr_enc >= 1


def test_layer_scale_constraints():
    w = np.array([[0.5, -0.25]])
    # weight-resolution bound
    assert abs(quantize.layer_scale(w, None) - 62.0) < 1e-6
    # threshold budget binds
    s = quantize.layer_scale(w, thr_f=100.0)
    assert abs(s - quantize.THETA_BUDGET / 100.0) < 1e-9
    # V-extreme budget binds
    s = quantize.layer_scale(w, None, v_max_f=1000.0)
    assert abs(s - quantize.V_BUDGET / 1000.0) < 1e-9


def test_digits_forward_shapes():
    params = model.init_digits_params(jax.random.PRNGKey(2))
    x = np.random.default_rng(1).random((2, 28, 28, 1)).astype(np.float32)
    logits, (rates, finals, ext) = model.digits_forward_float(params, jnp.asarray(x))
    assert logits.shape == (2, 10)
    assert rates.shape == (4,)
    assert ext.shape == (4,)


def test_int_infer_respects_11bit_range():
    data = datasets.make_sentiment(vocab_size=200, n_train=60, n_test=30, seed=12)
    params = model.init_sentiment_params(jax.random.PRNGKey(5))
    q = quantize.quantize_sentiment(params, data, v_extremes=[10.0, 10.0, 10.0])
    seqs, lens = datasets.pad_sequences(data.test_seqs[:10], 15)
    _, traces, _ = model.sentiment_infer_int(q, seqs, lens)
    assert traces.min() >= -1024 and traces.max() <= 1023
