"""IMPT tensor format + manifest round-trips (the Rust side has the
mirror suite in rust/src/data/binfmt.rs)."""

import numpy as np
import pytest

from compile import binfmt


@pytest.mark.parametrize(
    "arr",
    [
        np.arange(12, dtype=np.int32).reshape(3, 4) - 6,
        np.array([-32, 0, 31], dtype=np.int8),
        np.linspace(-1, 1, 7, dtype=np.float32),
        np.zeros((2, 2, 2), dtype=np.float64),
        np.array([3], dtype=np.int64),
        np.array([[0, 255]], dtype=np.uint8),
    ],
)
def test_tensor_roundtrip(tmp_path, arr):
    p = tmp_path / "t.bin"
    binfmt.write_tensor(p, arr)
    out = binfmt.read_tensor(p)
    assert out.dtype == arr.dtype
    assert out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        binfmt.read_tensor(p)


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(TypeError):
        binfmt.write_tensor(tmp_path / "x.bin", np.array([1 + 2j]))


def test_manifest_roundtrip(tmp_path):
    p = tmp_path / "m.txt"
    binfmt.write_manifest(p, {"b": 2, "a": "hello", "acc": 0.87})
    out = binfmt.read_manifest(p)
    assert out == {"a": "hello", "b": "2", "acc": "0.87"}
    # stable (sorted) order
    assert p.read_text().splitlines()[0].startswith("a=")
