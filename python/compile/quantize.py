"""Quantization from the float SNNs to IMPULSE's 6-bit weight / 11-bit
membrane-potential format.

Per mapped layer the float computation ``v += s_in @ W_f`` becomes
``v_q += s_in @ W_q`` with a single scale ``s_l`` per layer:

    W_q = round(W_f · s_l)  ∈ [-32, 31]       (6-bit signed)
    θ_q = round(θ_f · s_l)                     (11-bit, with headroom)

The scale trades weight resolution against V_MEM headroom: θ_q must
leave room below the ±1024 rails (wraparound corrupts the comparison,
see the engine tests), so ``s_l = min(31 / max|W_f|, θ_budget / θ_f)``.
"""

from __future__ import annotations

import numpy as np

from .datasets import SentimentData
from .model import QuantDigits, QuantSentiment

W_MAX = 31
THETA_BUDGET = 512  # keep θ_q ≤ 512 so |V| stays well under 1024
V_BUDGET = 960  # calibrated |V| must map inside the ±1024 rails
X_SCALE = 48.0  # input-current quantization for the (off-macro) encoder


def layer_scale(
    w_f: np.ndarray, thr_f: float | None, v_max_f: float | None = None
) -> float:
    """The joint weight/threshold scale for one mapped layer.

    ``thr_f`` is the float threshold (None for threshold-free output
    layers); ``v_max_f`` is the calibrated maximum |V| observed in float
    on the training set. The scale must map both inside the 11-bit
    rails or the macro's wraparound corrupts the dynamics
    (negative-drift spiking).
    """
    wmax = float(np.abs(w_f).max())
    s = W_MAX / max(wmax, 1e-6)
    if thr_f is not None:
        s = min(s, THETA_BUDGET / max(float(thr_f), 1e-6))
    if v_max_f is not None and v_max_f > 0:
        s = min(s, V_BUDGET / float(v_max_f))
    return s


def quantize_weights(w_f: np.ndarray, scale: float) -> np.ndarray:
    return np.clip(np.round(np.asarray(w_f) * scale), -32, 31).astype(np.int32)


def quantize_sentiment(
    params, data: SentimentData, v_extremes=None
) -> QuantSentiment:
    """Quantize the trained sentiment SNN.

    ``v_extremes`` — calibrated max |V| per layer (v1, v2, v_out) from a
    float forward pass over training data; see ``layer_scale``.
    """
    w1 = np.asarray(params["w1"])
    w2 = np.asarray(params["w2"])
    w_out = np.asarray(params["w_out"])
    thr_e = float(np.exp(params["log_thr_enc"]))
    thr1 = float(np.exp(params["log_thr1"]))
    thr2 = float(np.exp(params["log_thr2"]))
    ve = [None, None, None] if v_extremes is None else list(v_extremes)

    s1 = layer_scale(w1, thr1, ve[0])
    s2 = layer_scale(w2, thr2, ve[1])
    # Output layer has no threshold: the accumulated |V_out| must stay
    # under the 11-bit rails — the output neuron lives on the macro too.
    s_out = layer_scale(w_out, None, ve[2])

    emb_q = np.round(data.embeddings * X_SCALE).astype(np.int32)
    thr_enc_q = max(1, int(round(thr_e * X_SCALE)))

    return QuantSentiment(
        emb_q=emb_q,
        w1=quantize_weights(w1, s1),
        w2=quantize_weights(w2, s2),
        w_out=quantize_weights(w_out, s_out),
        thr_enc=thr_enc_q,
        thr1=max(1, int(round(thr1 * s1))),
        thr2=max(1, int(round(thr2 * s2))),
    )


def quantize_digits(params, v_extremes=None) -> QuantDigits:
    """Quantize the trained digits SNN (Conv1 encoder stays float).

    ``v_extremes`` — calibrated max |V| for (conv2, conv3, fc1, out).
    """
    k2 = np.asarray(params["k2"])
    k3 = np.asarray(params["k3"])
    wf1 = np.asarray(params["w_fc1"])
    wf2 = np.asarray(params["w_fc2"])
    thr2 = float(np.exp(params["log_thr_c2"]))
    thr3 = float(np.exp(params["log_thr_c3"]))
    thrf = float(np.exp(params["log_thr_f1"]))
    ve = [None] * 4 if v_extremes is None else list(v_extremes)

    s2 = layer_scale(k2, thr2, ve[0])
    s3 = layer_scale(k3, thr3, ve[1])
    sf = layer_scale(wf1, thrf, ve[2])
    s_out = layer_scale(wf2, None, ve[3])

    return QuantDigits(
        k1=np.asarray(params["k1"]).astype(np.float32),
        thr_c1_f=float(np.exp(params["log_thr_c1"])),
        k2=quantize_weights(k2, s2),
        k3=quantize_weights(k3, s3),
        w_fc1=quantize_weights(wf1, sf),
        w_fc2=quantize_weights(wf2, s_out),
        thr_c2=max(1, int(round(thr2 * s2))),
        thr_c3=max(1, int(round(thr3 * s3))),
        thr_f1=max(1, int(round(thrf * sf))),
    )
