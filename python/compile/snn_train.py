"""Surrogate-gradient BPTT training (Diet-SNN-style: threshold and
weight optimization) with a hand-rolled Adam (optax is not available in
the offline environment).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .datasets import DigitsData, SentimentData, pad_sequences

# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Sentiment training
# ---------------------------------------------------------------------------


def train_sentiment(
    data: SentimentData,
    epochs: int = 6,
    batch: int = 64,
    lr: float = 2e-3,
    max_len: int = 15,
    seed: int = 0,
    log=print,
):
    """Train the sentiment SNN; returns (params, history)."""
    key = jax.random.PRNGKey(seed)
    params = model.init_sentiment_params(key)
    opt = adam_init(params)

    seqs, lens = pad_sequences(data.train_seqs, max_len)
    labels = data.train_labels
    emb = data.embeddings

    @jax.jit
    def step(params, opt, emb_seq, mask, y):
        (loss, (v_out, aux)), grads = jax.value_and_grad(
            model.sentiment_loss, has_aux=True
        )(params, emb_seq, mask, y)
        params, opt = adam_update(params, grads, opt, lr=lr)
        acc = jnp.mean(((v_out >= 0).astype(jnp.uint8) == y).astype(jnp.float32))
        return params, opt, loss, acc, aux["spike_rates"]

    n = len(seqs)
    rng = np.random.default_rng(seed)
    history = []
    for epoch in range(epochs):
        order = rng.permutation(n)
        t0 = time.time()
        tot_loss, tot_acc, nb = 0.0, 0.0, 0
        for i in range(0, n - batch + 1, batch):
            ix = order[i : i + batch]
            emb_seq = emb[np.clip(seqs[ix], 0, None)]  # [B, L, 100]
            mask = (seqs[ix] >= 0).astype(np.float32)
            params, opt, loss, acc, rates = step(
                params, opt, jnp.asarray(emb_seq), jnp.asarray(mask), jnp.asarray(labels[ix])
            )
            tot_loss += float(loss)
            tot_acc += float(acc)
            nb += 1
        history.append(
            {
                "epoch": epoch,
                "loss": tot_loss / nb,
                "acc": tot_acc / nb,
                "secs": time.time() - t0,
                "spike_rates": [float(r) for r in rates],
            }
        )
        log(
            f"[sentiment] epoch {epoch}: loss={tot_loss/nb:.4f} "
            f"acc={tot_acc/nb:.4f} ({time.time()-t0:.1f}s) rates={rates}"
        )
    return params, history


def eval_sentiment_float(params, data: SentimentData, max_len: int = 15, batch: int = 200):
    seqs, lens = pad_sequences(data.test_seqs, max_len)
    emb = data.embeddings
    correct = 0
    fwd = jax.jit(lambda p, e, m: model.sentiment_forward_float(p, e, m)[0])
    for i in range(0, len(seqs), batch):
        sl = seqs[i : i + batch]
        emb_seq = emb[np.clip(sl, 0, None)]
        mask = (sl >= 0).astype(np.float32)
        v_out = fwd(params, jnp.asarray(emb_seq), jnp.asarray(mask))
        preds = (np.asarray(v_out) >= 0).astype(np.uint8)
        correct += int((preds == data.test_labels[i : i + batch]).sum())
    return correct / len(seqs)


# ---------------------------------------------------------------------------
# Digits training
# ---------------------------------------------------------------------------


def train_digits(
    data: DigitsData,
    epochs: int = 4,
    batch: int = 64,
    lr: float = 2e-3,
    seed: int = 0,
    log=print,
):
    key = jax.random.PRNGKey(seed + 100)
    params = model.init_digits_params(key)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, y):
        (loss, (logits, rates, _ext)), grads = jax.value_and_grad(
            model.digits_loss, has_aux=True
        )(params, x, y)
        params, opt = adam_update(params, grads, opt, lr=lr)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return params, opt, loss, acc, rates

    x = data.train_x[..., None]
    y = data.train_y.astype(np.int32)
    n = len(y)
    rng = np.random.default_rng(seed)
    history = []
    for epoch in range(epochs):
        order = rng.permutation(n)
        t0 = time.time()
        tot_loss, tot_acc, nb = 0.0, 0.0, 0
        for i in range(0, n - batch + 1, batch):
            ix = order[i : i + batch]
            params, opt, loss, acc, rates = step(
                params, opt, jnp.asarray(x[ix]), jnp.asarray(y[ix])
            )
            tot_loss += float(loss)
            tot_acc += float(acc)
            nb += 1
        history.append(
            {
                "epoch": epoch,
                "loss": tot_loss / nb,
                "acc": tot_acc / nb,
                "secs": time.time() - t0,
            }
        )
        log(
            f"[digits] epoch {epoch}: loss={tot_loss/nb:.4f} acc={tot_acc/nb:.4f} "
            f"({time.time()-t0:.1f}s) rates={rates}"
        )
    return params, history


def eval_digits_float(params, data: DigitsData, batch: int = 200):
    fwd = jax.jit(lambda p, x: model.digits_forward_float(p, x)[0])
    correct = 0
    for i in range(0, len(data.test_y), batch):
        logits = fwd(params, jnp.asarray(data.test_x[i : i + batch][..., None]))
        preds = np.asarray(jnp.argmax(logits, -1))
        correct += int((preds == data.test_y[i : i + batch]).sum())
    return correct / len(data.test_y)
