"""The paper's comparison baseline: a 2-layer LSTM (100→128→128→1,
no biases — 247,808 ≈ 247.8K parameters, exactly the count the paper
reports, 8.5× the SNN's 29.3K).

Trained on the same synthetic corpus; its weights and accuracy are
exported so the Rust baseline (`rust/src/baselines/lstm.rs`) can run
the identical model for the Fig 9(b) comparison.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .datasets import SentimentData, pad_sequences
from .snn_train import adam_init, adam_update

HIDDEN = 128


def init_lstm_params(key, emb=100, hidden=HIDDEN):
    ks = jax.random.split(key, 5)
    glorot = jax.nn.initializers.glorot_uniform()
    return {
        # layer 1: input 100 → hidden 128; 4 gates stacked [4H]
        "wx1": glorot(ks[0], (emb, 4 * hidden), jnp.float32),
        "wh1": glorot(ks[1], (hidden, 4 * hidden), jnp.float32),
        # layer 2: 128 → 128
        "wx2": glorot(ks[2], (hidden, 4 * hidden), jnp.float32),
        "wh2": glorot(ks[3], (hidden, 4 * hidden), jnp.float32),
        "w_out": glorot(ks[4], (hidden, 1), jnp.float32),
    }


def count_lstm_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def _cell(x, h, c, wx, wh):
    z = x @ wx + h @ wh
    i, f, g, o = jnp.split(z, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, c


def lstm_forward(params, emb_seq, mask):
    """emb_seq: [B, L, 100]; mask: [B, L]. Returns logits [B]."""
    b, l, _ = emb_seq.shape
    h1 = jnp.zeros((b, HIDDEN))
    c1 = jnp.zeros((b, HIDDEN))
    h2 = jnp.zeros((b, HIDDEN))
    c2 = jnp.zeros((b, HIDDEN))

    def step(carry, inputs):
        h1, c1, h2, c2 = carry
        x, m = inputs
        nh1, nc1 = _cell(x, h1, c1, params["wx1"], params["wh1"])
        nh2, nc2 = _cell(nh1, h2, c2, params["wx2"], params["wh2"])
        m1 = m[:, None]
        carry = (
            m1 * nh1 + (1 - m1) * h1,
            m1 * nc1 + (1 - m1) * c1,
            m1 * nh2 + (1 - m1) * h2,
            m1 * nc2 + (1 - m1) * c2,
        )
        return carry, None

    (h1, c1, h2, c2), _ = jax.lax.scan(
        step, (h1, c1, h2, c2), (jnp.swapaxes(emb_seq, 0, 1), jnp.swapaxes(mask, 0, 1))
    )
    return (h2 @ params["w_out"])[:, 0]


def lstm_loss(params, emb_seq, mask, labels):
    logits = lstm_forward(params, emb_seq, mask)
    y = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    ), logits


def train_lstm(
    data: SentimentData,
    epochs: int = 5,
    batch: int = 64,
    lr: float = 2e-3,
    max_len: int = 15,
    seed: int = 1,
    log=print,
):
    key = jax.random.PRNGKey(seed)
    params = init_lstm_params(key)
    opt = adam_init(params)
    seqs, _ = pad_sequences(data.train_seqs, max_len)
    labels = data.train_labels
    emb = data.embeddings

    @jax.jit
    def step(params, opt, e, m, y):
        (loss, logits), grads = jax.value_and_grad(lstm_loss, has_aux=True)(
            params, e, m, y
        )
        params, opt = adam_update(params, grads, opt, lr=lr)
        acc = jnp.mean(((logits >= 0).astype(jnp.uint8) == y).astype(jnp.float32))
        return params, opt, loss, acc

    n = len(seqs)
    rng = np.random.default_rng(seed)
    history = []
    for epoch in range(epochs):
        order = rng.permutation(n)
        t0 = time.time()
        tot_loss, tot_acc, nb = 0.0, 0.0, 0
        for i in range(0, n - batch + 1, batch):
            ix = order[i : i + batch]
            e = emb[np.clip(seqs[ix], 0, None)]
            m = (seqs[ix] >= 0).astype(np.float32)
            params, opt, loss, acc = step(
                params, opt, jnp.asarray(e), jnp.asarray(m), jnp.asarray(labels[ix])
            )
            tot_loss += float(loss)
            tot_acc += float(acc)
            nb += 1
        history.append({"epoch": epoch, "loss": tot_loss / nb, "acc": tot_acc / nb})
        log(
            f"[lstm] epoch {epoch}: loss={tot_loss/nb:.4f} acc={tot_acc/nb:.4f} "
            f"({time.time()-t0:.1f}s)"
        )
    return params, history


def eval_lstm(params, data: SentimentData, max_len: int = 15, batch: int = 200):
    seqs, _ = pad_sequences(data.test_seqs, max_len)
    emb = data.embeddings
    fwd = jax.jit(lstm_forward)
    correct = 0
    for i in range(0, len(seqs), batch):
        sl = seqs[i : i + batch]
        e = emb[np.clip(sl, 0, None)]
        m = (sl >= 0).astype(np.float32)
        logits = fwd(params, jnp.asarray(e), jnp.asarray(m))
        preds = (np.asarray(logits) >= 0).astype(np.uint8)
        correct += int((preds == data.test_labels[i : i + batch]).sum())
    return correct / len(seqs)
