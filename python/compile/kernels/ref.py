"""Pure-jnp oracle for the fused SNN timestep — the correctness
reference the Pallas kernel (and, via exported test vectors, the Rust
macro simulator) is validated against.

Semantics are hardware-exact (see DESIGN.md §5):

* membrane potentials live in 11-bit two's complement and *wrap* on
  overflow (the ripple adder drops the final carry);
* the threshold comparison itself goes through the same adder, so the
  spike decision is ``wrap11(V − θ) ≥ 0`` — including the wraparound
  artifact for deeply-negative V;
* neuron modes follow the paper's instruction sequences: IF (hard
  reset), LIF (subtractive leak, hard reset), RMP (soft reset).
"""

from __future__ import annotations

import jax.numpy as jnp

V_BITS = 11
W_BITS = 6

IF, LIF, RMP = 0, 1, 2


def wrap11(x: jnp.ndarray) -> jnp.ndarray:
    """Wrap int32 values into 11-bit two's complement [-1024, 1023].

    Exactly what an 11-bit ripple-carry adder computes when the final
    carry-out is dropped.
    """
    m = 1 << V_BITS
    half = m >> 1
    return ((x % m) + m + half) % m - half


def spike_of(v: jnp.ndarray, threshold) -> jnp.ndarray:
    """Hardware SpikeCheck: sign of the in-array subtraction (wrapped)."""
    return (wrap11(v - threshold) >= 0).astype(jnp.int32)


def snn_step_ref(
    spikes: jnp.ndarray,  # [B, M] int32 in {0,1}
    weights: jnp.ndarray,  # [M, N] int32 (6-bit signed values)
    v: jnp.ndarray,  # [B, N] int32 (11-bit wrapped)
    threshold: int,
    mode: int = RMP,
    leak: int = 0,
    reset: int = 0,
):
    """One fused layer timestep: accumulate → (leak) → threshold → reset.

    Returns ``(v_next, out_spikes)``, both int32, with ``v_next`` in
    [-1024, 1023]. Accumulate-then-wrap equals the hardware's
    wrap-after-each-add because wrapping is mod-2^11 arithmetic.
    """
    acc = jnp.matmul(spikes, weights, preferred_element_type=jnp.int32)
    v1 = wrap11(v + acc)
    if mode == LIF:
        v1 = wrap11(v1 - leak)
    s = spike_of(v1, threshold)
    if mode == RMP:
        v2 = jnp.where(s == 1, wrap11(v1 - threshold), v1)
    else:  # IF and LIF share the hard reset
        v2 = jnp.where(s == 1, jnp.full_like(v1, reset), v1)
    return v2, s


def encoder_step_ref(
    x_q: jnp.ndarray,  # [B, M] int32 quantized input current
    v: jnp.ndarray,  # [B, M] int32 (32-bit, off-macro: no 11-bit wrap)
    threshold,
):
    """Direct-input spike encoder step (the network's input layer).

    The encoder is *not* mapped on IMPULSE (the paper: "the input layer
    acts as spike-encoder"), so its state is plain int32 with RMP-style
    soft reset and no wraparound.
    """
    v1 = v + x_q
    s = (v1 >= threshold).astype(jnp.int32)
    v2 = jnp.where(s == 1, v1 - threshold, v1)
    return v2, s
