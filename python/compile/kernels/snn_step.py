"""Pallas kernel for the fused SNN layer timestep (Layer 1).

The paper's insight — fuse the recurrent state (V_MEM) with the weights
so a timestep's accumulate → threshold → reset chain happens *in place*,
with input-spike sparsity gating the work — maps onto the TPU memory
hierarchy as a single kernel that keeps the weight tile and the
membrane-potential tile resident in VMEM and performs the whole update
without intermediate round-trips to HBM (DESIGN.md §2
Hardware-Adaptation).

Tiling: the grid walks output-neuron tiles of width ``block_n`` (the
analogue of the macro's six 12-column fields) and batch tiles of height
``block_b``. Each program instance sees:

* ``spikes  [block_b, M]`` — the binary input spike slab,
* ``weights [M, block_n]`` — its weight stripe (VMEM-resident),
* ``v       [block_b, block_n]`` — its membrane-potential tile,

and writes the updated potentials plus the output spikes. The MXU path
computes the spike-gated accumulation as an integer matmul (spikes are
{0,1}, so the matmul *is* the sparsity-masked column sum the silicon
performs with AccW2V instructions).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is also what
``aot.py`` exports for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import IF, LIF, RMP, V_BITS


def _wrap11(x):
    # Bit-twiddled wrap (cheaper than the mod form inside the kernel):
    # interpret the low 11 bits as two's complement.
    m = (1 << V_BITS) - 1
    half = 1 << (V_BITS - 1)
    return ((x & m) ^ half) - half


def _snn_step_kernel(s_ref, w_ref, v_ref, thr_ref, leak_ref, reset_ref,
                     v_out_ref, s_out_ref, *, mode: int):
    """One (batch-tile × neuron-tile) fused update."""
    spikes = s_ref[...]
    weights = w_ref[...]
    v = v_ref[...]
    thr = thr_ref[0, 0]
    leak = leak_ref[0, 0]
    reset = reset_ref[0, 0]

    # AccW2V: spike-gated column accumulation == integer matmul on the
    # {0,1} spike slab. preferred_element_type keeps the MXU path int32.
    acc = jnp.matmul(spikes, weights, preferred_element_type=jnp.int32)
    v1 = _wrap11(v + acc)
    if mode == LIF:
        v1 = _wrap11(v1 - leak)
    # SpikeCheck: the comparison itself rides the 11-bit adder.
    s = (_wrap11(v1 - thr) >= 0).astype(jnp.int32)
    if mode == RMP:
        v2 = jnp.where(s == 1, _wrap11(v1 - thr), v1)
    else:
        v2 = jnp.where(s == 1, jnp.broadcast_to(reset, v1.shape), v1)
    v_out_ref[...] = v2
    s_out_ref[...] = s


@functools.partial(
    jax.jit,
    static_argnames=("mode", "block_b", "block_n"),
)
def snn_step(
    spikes: jnp.ndarray,  # [B, M] int32 {0,1}
    weights: jnp.ndarray,  # [M, N] int32
    v: jnp.ndarray,  # [B, N] int32
    threshold,
    mode: int = RMP,
    leak=0,
    reset=0,
    block_b: int = 8,
    block_n: int = 64,
):
    """Fused SNN layer timestep as a Pallas call.

    Returns ``(v_next, out_spikes)``. Matches ``ref.snn_step_ref``
    bit-exactly for all inputs (hypothesis-swept in the test suite).
    """
    b, m = spikes.shape
    m2, n = weights.shape
    assert m == m2, f"fan-in mismatch {m} vs {m2}"
    assert v.shape == (b, n)

    bb = min(block_b, b)
    bn = min(block_n, n)
    grid = (pl.cdiv(b, bb), pl.cdiv(n, bn))

    thr_a = jnp.asarray(threshold, jnp.int32).reshape(1, 1)
    leak_a = jnp.asarray(leak, jnp.int32).reshape(1, 1)
    reset_a = jnp.asarray(reset, jnp.int32).reshape(1, 1)

    kernel = functools.partial(_snn_step_kernel, mode=mode)
    v_next, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, m), lambda i, j: (i, 0)),
            pl.BlockSpec((m, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n), jnp.int32),
        ],
        interpret=True,
    )(spikes, weights, v, thr_a, leak_a, reset_a)
    return v_next, s_out


def _encoder_kernel(x_ref, v_ref, thr_ref, v_out_ref, s_out_ref):
    x = x_ref[...]
    v = v_ref[...]
    thr = thr_ref[0, 0]
    v1 = v + x
    s = (v1 >= thr).astype(jnp.int32)
    v_out_ref[...] = jnp.where(s == 1, v1 - thr, v1)
    s_out_ref[...] = s


@functools.partial(jax.jit, static_argnames=("block_b",))
def encoder_step(
    x_q: jnp.ndarray,  # [B, M] int32
    v: jnp.ndarray,  # [B, M] int32
    threshold,
    block_b: int = 8,
):
    """Direct-input spike-encoder step as a Pallas call (off-macro
    layer; plain int32, RMP-style soft reset, no 11-bit wrap)."""
    b, m = x_q.shape
    bb = min(block_b, b)
    grid = (pl.cdiv(b, bb),)
    thr_a = jnp.asarray(threshold, jnp.int32).reshape(1, 1)
    v_next, s = pl.pallas_call(
        _encoder_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
        ],
        interpret=True,
    )(x_q, v, thr_a)
    return v_next, s
