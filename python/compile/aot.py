"""AOT build orchestrator: the ONLY Python entry point in the build.

``python -m compile.aot --out ../artifacts`` runs once at build time:

1. generate the synthetic datasets (IMDB/GloVe + MNIST stand-ins),
2. train the sentiment SNN, the digits SNN, and the LSTM baseline,
3. quantize to IMPULSE's 6-bit/11-bit format and evaluate,
4. export: quantized weights + embeddings + test sets (IMPT binary
   tensors), kernel cross-check vectors, the quantized per-timestep
   sentiment graph as **HLO text** for the Rust PJRT runtime, and a
   manifest with every measured number.

The export is cached: if ``manifest.txt`` exists and records the same
source digest, the whole step is a no-op (Python never runs again; the
Rust binary is self-contained).

HLO text — not a serialized HloModuleProto — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import binfmt, datasets, lstm_baseline, model, quantize, snn_train
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """Convert a jax-lowered computation to XLA HLO text."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def source_digest() -> str:
    """Digest of the compile-path sources + config env (cache key)."""
    h = hashlib.sha256()
    src = Path(__file__).parent
    for p in sorted(src.rglob("*.py")):
        h.update(p.read_bytes())
    for var in ("IMPULSE_EPOCHS", "IMPULSE_FAST"):
        h.update(f"{var}={os.environ.get(var, '')}".encode())
    return h.hexdigest()[:16]


def export_sentiment_hlo(q: model.QuantSentiment, out: Path) -> str:
    """AOT-lower the quantized per-timestep sentiment step (batch=1).

    The weight matrices are graph *parameters*, not baked constants:
    ``XlaComputation.as_hlo_text()`` elides large constants as
    ``{...}``, which the Rust side's HLO text parser cannot recover
    (discovered the hard way — see EXPERIMENTS.md §Gotchas). The Rust
    runtime owns the weights (loaded from the .bin artifacts) and feeds
    them with every call; thresholds are small scalars and stay baked.
    """

    def step(x_q, v_e, v1, v2, v_o, w1, w2, w_out):
        v_e, v1, v2, v_o, (s0, s1, s2) = model.sentiment_step_int(
            w1, w2, w_out, q.thr_enc, q.thr1, q.thr2, x_q, v_e, v1, v2, v_o
        )
        return v_e, v1, v2, v_o, s1, s2

    m, h1, h2 = q.w1.shape[0], q.w1.shape[1], q.w2.shape[1]
    spec = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    lowered = jax.jit(step).lower(
        spec((1, m)), spec((1, m)), spec((1, h1)), spec((1, h2)), spec((1, 1)),
        spec((m, h1)), spec((h1, h2)), spec((h2, 1)),
    )
    text = to_hlo_text(lowered)
    assert "constant({...})" not in text, "elided large constant in HLO text"
    (out / "sentiment_step.hlo.txt").write_text(text)
    return text


def export_kernel_vectors(out: Path, seed: int = 123) -> None:
    """Random fused-step test vectors: inputs + oracle outputs, for the
    Rust side to cross-check its golden/macro engines against L1."""
    rng = np.random.default_rng(seed)
    cases = [
        ("rmp_128x128", 128, 128, ref.RMP, 200, 0),
        ("if_100x128", 100, 128, ref.IF, 150, 0),
        ("lif_64x32", 64, 32, ref.LIF, 100, 3),
        ("rmp_5x6", 5, 6, ref.RMP, 20, 0),
    ]
    names = []
    for name, m, n, mode, thr, leak in cases:
        spikes = (rng.random((4, m)) < 0.2).astype(np.int32)
        weights = rng.integers(-32, 32, size=(m, n)).astype(np.int32)
        v = rng.integers(-900, 900, size=(4, n)).astype(np.int32)
        v2, s = ref.snn_step_ref(
            jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(v),
            thr, mode=mode, leak=leak,
        )
        d = out / "kernel_vectors"
        binfmt.write_tensor(d / f"{name}_spikes.bin", spikes)
        binfmt.write_tensor(d / f"{name}_weights.bin", weights)
        binfmt.write_tensor(d / f"{name}_v.bin", v)
        binfmt.write_tensor(d / f"{name}_v_next.bin", np.asarray(v2))
        binfmt.write_tensor(d / f"{name}_spikes_out.bin", np.asarray(s))
        binfmt.write_tensor(
            d / f"{name}_meta.bin",
            np.array([mode, thr, leak], dtype=np.int32),
        )
        names.append(name)
    (out / "kernel_vectors" / "index.txt").write_text("\n".join(names) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    digest = source_digest()
    manifest_path = out / "manifest.txt"
    if manifest_path.exists() and not args.force:
        old = binfmt.read_manifest(manifest_path)
        if old.get("source_digest") == digest:
            print(f"artifacts up to date (digest {digest}); skipping")
            return

    fast = os.environ.get("IMPULSE_FAST", "") == "1"
    epochs = int(os.environ.get("IMPULSE_EPOCHS", "2" if fast else "6"))
    t_start = time.time()
    man: dict = {"source_digest": digest, "fast_mode": int(fast)}

    # ---------------- datasets ----------------
    print("== generating datasets")
    sent = datasets.make_sentiment(
        n_train=1000 if fast else 4000, n_test=300 if fast else 1000
    )
    digits = datasets.make_digits(
        n_train=600 if fast else 3000, n_test=200 if fast else 1000
    )
    man["sentiment_vocab"] = sent.embeddings.shape[0]
    man["sentiment_train"] = len(sent.train_seqs)
    man["sentiment_test"] = len(sent.test_seqs)
    man["digits_train"] = len(digits.train_y)
    man["digits_test"] = len(digits.test_y)

    # ---------------- sentiment SNN ----------------
    print("== training sentiment SNN")
    params, hist = snn_train.train_sentiment(sent, epochs=epochs)
    float_acc = snn_train.eval_sentiment_float(params, sent)
    n_params = model.count_sentiment_params(params)
    print(f"   float test acc {float_acc:.4f}, {n_params} params")
    man["snn_sentiment_float_acc"] = f"{float_acc:.4f}"
    man["snn_sentiment_params"] = n_params

    # calibration: float |V| extremes over a training slice drive the
    # quantization scales (the net must fit the 11-bit rails)
    cal_seqs, _ = datasets.pad_sequences(sent.train_seqs[:256], 15)
    cal_emb = sent.embeddings[np.clip(cal_seqs, 0, None)]
    cal_mask = (cal_seqs >= 0).astype(np.float32)
    _, cal_aux = jax.jit(model.sentiment_forward_float)(
        params, jnp.asarray(cal_emb), jnp.asarray(cal_mask)
    )
    v_ext = [float(x) for x in np.asarray(cal_aux["v_extremes"])]
    man["sentiment_v_extremes"] = ",".join(f"{x:.2f}" for x in v_ext)

    q = quantize.quantize_sentiment(params, sent, v_extremes=v_ext)
    seqs, lens = datasets.pad_sequences(sent.test_seqs, 15)
    preds, traces, sparsity = model.sentiment_infer_int(q, seqs, lens)
    q_acc = float((preds == sent.test_labels).mean())
    print(f"   quantized test acc {q_acc:.4f}, layer sparsity {sparsity}")
    man["snn_sentiment_quant_acc"] = f"{q_acc:.4f}"
    for i, s in enumerate(sparsity):
        man[f"snn_sentiment_sparsity_l{i}"] = f"{float(s):.4f}"
    man["snn_thr_enc"] = q.thr_enc
    man["snn_thr1"] = q.thr1
    man["snn_thr2"] = q.thr2

    sdir = out / "sentiment"
    binfmt.write_tensor(sdir / "emb_q.bin", q.emb_q)
    binfmt.write_tensor(sdir / "w1.bin", q.w1.astype(np.int8))
    binfmt.write_tensor(sdir / "w2.bin", q.w2.astype(np.int8))
    binfmt.write_tensor(sdir / "w_out.bin", q.w_out.astype(np.int8))
    binfmt.write_tensor(sdir / "test_seqs.bin", seqs)
    binfmt.write_tensor(sdir / "test_lens.bin", lens)
    binfmt.write_tensor(sdir / "test_labels.bin", sent.test_labels)
    binfmt.write_tensor(sdir / "polarity.bin", sent.polarity)
    # reference integer traces for differential testing (first 32)
    binfmt.write_tensor(sdir / "ref_vout_traces.bin", traces[:32].astype(np.int32))
    binfmt.write_tensor(sdir / "ref_preds.bin", preds)

    print("== exporting sentiment HLO")
    hlo = export_sentiment_hlo(q, out)
    man["sentiment_hlo_bytes"] = len(hlo)

    # ---------------- LSTM baseline ----------------
    print("== training LSTM baseline")
    lparams, _ = lstm_baseline.train_lstm(sent, epochs=max(2, epochs - 1))
    lstm_acc = lstm_baseline.eval_lstm(lparams, sent)
    lstm_n = lstm_baseline.count_lstm_params(lparams)
    print(f"   LSTM test acc {lstm_acc:.4f}, {lstm_n} params")
    man["lstm_acc"] = f"{lstm_acc:.4f}"
    man["lstm_params"] = lstm_n
    ldir = out / "lstm"
    for k, v in lparams.items():
        binfmt.write_tensor(ldir / f"{k}.bin", np.asarray(v, dtype=np.float32))

    # ---------------- digits SNN ----------------
    print("== training digits SNN")
    dparams, _ = snn_train.train_digits(digits, epochs=max(2, epochs - 2))
    d_acc = snn_train.eval_digits_float(dparams, digits)
    print(f"   digits float acc {d_acc:.4f}")
    man["snn_digits_float_acc"] = f"{d_acc:.4f}"
    man["snn_digits_params"] = model.count_digits_params(dparams)

    _, (_, _, d_ext) = jax.jit(model.digits_forward_float)(
        dparams, jnp.asarray(digits.train_x[:256][..., None])
    )
    d_ext = [float(x) for x in np.asarray(d_ext)]
    man["digits_v_extremes"] = ",".join(f"{x:.2f}" for x in d_ext)

    qd = quantize.quantize_digits(dparams, v_extremes=d_ext)
    dpreds, dsparsity = model.digits_infer_int(
        qd, jnp.asarray(digits.test_x[:500][..., None])
    )
    dq_acc = float((dpreds == digits.test_y[:500]).mean())
    print(f"   digits quantized acc {dq_acc:.4f}, sparsity {dsparsity}")
    man["snn_digits_quant_acc"] = f"{dq_acc:.4f}"
    for i, s in enumerate(dsparsity):
        man[f"snn_digits_sparsity_l{i}"] = f"{float(s):.4f}"

    ddir = out / "digits"
    binfmt.write_tensor(ddir / "k1.bin", qd.k1)
    binfmt.write_tensor(ddir / "k2.bin", qd.k2.astype(np.int8))
    binfmt.write_tensor(ddir / "k3.bin", qd.k3.astype(np.int8))
    binfmt.write_tensor(ddir / "w_fc1.bin", qd.w_fc1.astype(np.int8))
    binfmt.write_tensor(ddir / "w_fc2.bin", qd.w_fc2.astype(np.int8))
    binfmt.write_tensor(
        ddir / "thresholds.bin",
        np.array([qd.thr_c2, qd.thr_c3, qd.thr_f1], dtype=np.int32),
    )
    binfmt.write_tensor(ddir / "thr_c1.bin", np.array([qd.thr_c1_f], dtype=np.float32))
    binfmt.write_tensor(ddir / "test_x.bin", digits.test_x)
    binfmt.write_tensor(ddir / "test_y.bin", digits.test_y)
    man["digits_thr_c2"] = qd.thr_c2
    man["digits_thr_c3"] = qd.thr_c3
    man["digits_thr_f1"] = qd.thr_f1

    # ---------------- kernel cross-check vectors ----------------
    print("== exporting kernel vectors")
    export_kernel_vectors(out)

    man["build_seconds"] = f"{time.time() - t_start:.1f}"
    binfmt.write_manifest(manifest_path, man)
    print(f"== done in {man['build_seconds']}s → {out}")


if __name__ == "__main__":
    sys.exit(main())
