"""Synthetic datasets standing in for IMDB+GloVe and MNIST.

The environment has no network access, so the paper's corpora cannot be
downloaded. These generators produce structurally-equivalent workloads
(DESIGN.md §1 documents the substitution):

* **Sentiment** — a vocabulary of ``vocab_size`` pseudo-words, each with
  a fixed 100-d embedding (the "GloVe" stand-in). A latent sentiment
  direction is planted in embedding space: polar words' embeddings lean
  ±along it. A review is a variable-length word sequence whose label is
  the sign of its summed polarity (plus distractor words and noise), so
  classifying it requires *integrating evidence across the sequence* —
  the same sequential-memory demand the paper puts on V_MEM.

* **Digits** — procedurally rendered 28×28 glyphs (10 classes) from
  stroke skeletons with random shift/jitter/noise/thickness, an MNIST
  stand-in exercising the identical Conv-SNN path.

Everything is deterministic given the seed.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

EMB_DIM = 100


@dataclass
class SentimentData:
    embeddings: np.ndarray  # [vocab, 100] f32
    polarity: np.ndarray  # [vocab] i8 in {-1, 0, +1}
    train_seqs: list[np.ndarray]  # word-id arrays
    train_labels: np.ndarray  # [n] u8 (0/1)
    test_seqs: list[np.ndarray]
    test_labels: np.ndarray


def make_sentiment(
    vocab_size: int = 2000,
    n_train: int = 4000,
    n_test: int = 1000,
    min_len: int = 5,
    max_len: int = 15,
    polar_frac: float = 0.3,
    seed: int = 7,
) -> SentimentData:
    """Generate the synthetic sentiment corpus."""
    rng = np.random.default_rng(seed)

    # Embedding table: random base + planted sentiment direction.
    base = rng.normal(0.0, 0.35, size=(vocab_size, EMB_DIM)).astype(np.float32)
    direction = rng.normal(0.0, 1.0, size=(EMB_DIM,))
    direction /= np.linalg.norm(direction)
    polarity = np.zeros(vocab_size, dtype=np.int8)
    n_polar = int(vocab_size * polar_frac)
    polar_ids = rng.choice(vocab_size, size=n_polar, replace=False)
    signs = rng.choice([-1, 1], size=n_polar)
    polarity[polar_ids] = signs
    strength = rng.uniform(0.4, 1.0, size=(vocab_size, 1)).astype(np.float32)
    emb = base + polarity[:, None] * strength * direction[None, :].astype(np.float32)
    emb = emb.astype(np.float32)

    neutral_ids = np.where(polarity == 0)[0]
    pos_ids = np.where(polarity == 1)[0]
    neg_ids = np.where(polarity == -1)[0]

    def gen_split(n: int):
        seqs, labels = [], []
        for _ in range(n):
            label = int(rng.integers(0, 2))
            length = int(rng.integers(min_len, max_len + 1))
            # Draw counts: the labelled class dominates but the other
            # polarity also appears (mixed evidence must be integrated).
            n_dom = int(rng.integers(2, max(3, length // 2 + 2)))
            n_opp = int(rng.integers(0, max(1, n_dom - 1)))
            n_neu = max(0, length - n_dom - n_opp)
            dom = pos_ids if label == 1 else neg_ids
            opp = neg_ids if label == 1 else pos_ids
            words = np.concatenate(
                [
                    rng.choice(dom, size=n_dom),
                    rng.choice(opp, size=n_opp),
                    rng.choice(neutral_ids, size=n_neu),
                ]
            )
            rng.shuffle(words)
            seqs.append(words.astype(np.int32))
            labels.append(label)
        return seqs, np.array(labels, dtype=np.uint8)

    train_seqs, train_labels = gen_split(n_train)
    test_seqs, test_labels = gen_split(n_test)
    return SentimentData(emb, polarity, train_seqs, train_labels, test_seqs, test_labels)


# ---------------------------------------------------------------------------
# Digits
# ---------------------------------------------------------------------------

# Stroke skeletons on a 7-point grid (x, y in [0, 1]), one polyline list
# per digit. Rendered with thickness + jitter into 28×28.
_SKELETONS: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.3, 0.15), (0.7, 0.15), (0.85, 0.4), (0.85, 0.6), (0.7, 0.85), (0.3, 0.85), (0.15, 0.6), (0.15, 0.4), (0.3, 0.15)]],
    1: [[(0.35, 0.25), (0.55, 0.12), (0.55, 0.88)], [(0.35, 0.88), (0.75, 0.88)]],
    2: [[(0.2, 0.3), (0.35, 0.12), (0.65, 0.12), (0.8, 0.3), (0.75, 0.5), (0.2, 0.88), (0.8, 0.88)]],
    3: [[(0.2, 0.15), (0.75, 0.15), (0.45, 0.45), (0.8, 0.65), (0.7, 0.88), (0.25, 0.9)]],
    4: [[(0.65, 0.88), (0.65, 0.12), (0.18, 0.6), (0.85, 0.6)]],
    5: [[(0.8, 0.12), (0.25, 0.12), (0.22, 0.45), (0.65, 0.45), (0.8, 0.65), (0.65, 0.88), (0.2, 0.85)]],
    6: [[(0.7, 0.12), (0.35, 0.35), (0.2, 0.65), (0.35, 0.88), (0.7, 0.85), (0.8, 0.62), (0.55, 0.5), (0.25, 0.6)]],
    7: [[(0.18, 0.12), (0.82, 0.12), (0.45, 0.88)]],
    8: [[(0.5, 0.12), (0.75, 0.28), (0.3, 0.6), (0.25, 0.8), (0.5, 0.9), (0.75, 0.8), (0.3, 0.28), (0.5, 0.12)]],
    9: [[(0.75, 0.4), (0.5, 0.5), (0.25, 0.38), (0.3, 0.15), (0.6, 0.1), (0.75, 0.3), (0.7, 0.7), (0.5, 0.9)]],
}


def _render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), dtype=np.float32)
    dx, dy = rng.uniform(-2.0, 2.0, size=2)
    scale = rng.uniform(0.85, 1.1)
    thick = rng.uniform(0.7, 1.4)
    for stroke in _SKELETONS[digit]:
        pts = np.array(stroke, dtype=np.float64)
        pts += rng.normal(0, 0.02, size=pts.shape)  # jitter control points
        # densify the polyline
        dense = []
        for a, b in zip(pts[:-1], pts[1:]):
            for t in np.linspace(0, 1, 20):
                dense.append(a + t * (b - a))
        for p in dense:
            cx = (p[0] - 0.5) * scale * 24 + 13.5 + dx
            cy = (p[1] - 0.5) * scale * 24 + 13.5 + dy
            x0, x1 = int(np.floor(cx - thick)), int(np.ceil(cx + thick))
            y0, y1 = int(np.floor(cy - thick)), int(np.ceil(cy + thick))
            for yy in range(max(0, y0), min(28, y1 + 1)):
                for xx in range(max(0, x0), min(28, x1 + 1)):
                    d2 = (xx - cx) ** 2 + (yy - cy) ** 2
                    img[yy, xx] = max(img[yy, xx], float(np.exp(-d2 / (thick**2))))
    img += rng.normal(0, 0.03, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


@dataclass
class DigitsData:
    train_x: np.ndarray  # [n, 28, 28] f32
    train_y: np.ndarray  # [n] u8
    test_x: np.ndarray
    test_y: np.ndarray


def make_digits(n_train: int = 3000, n_test: int = 1000, seed: int = 11) -> DigitsData:
    """Generate the synthetic digit dataset."""
    rng = np.random.default_rng(seed)

    def split(n):
        xs = np.zeros((n, 28, 28), dtype=np.float32)
        ys = np.zeros(n, dtype=np.uint8)
        for i in range(n):
            d = int(rng.integers(0, 10))
            xs[i] = _render_digit(d, rng)
            ys[i] = d
        return xs, ys

    train_x, train_y = split(n_train)
    test_x, test_y = split(n_test)
    return DigitsData(train_x, train_y, test_x, test_y)


def pad_sequences(seqs: list[np.ndarray], max_len: int, pad_id: int = -1):
    """Pad word-id sequences to [n, max_len] plus a length vector."""
    n = len(seqs)
    out = np.full((n, max_len), pad_id, dtype=np.int32)
    lens = np.zeros(n, dtype=np.int32)
    for i, s in enumerate(seqs):
        m = min(len(s), max_len)
        out[i, :m] = s[:m]
        lens[i] = m
    return out, lens
