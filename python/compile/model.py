"""Layer-2 JAX models: the paper's SNNs in float (training) and in
hardware-exact integer form (inference, calling the Pallas kernel).

Two networks, matching §III of the paper:

* **Sentiment SNN** — input layer (100 neurons, spike encoder), two FC
  layers (128 RMP neurons each) mapped on IMPULSE, output neuron whose
  membrane potential integrates evidence across the word sequence
  (sign ⇒ sentiment). 29.3K trainable parameters.
* **Digits SNN** — modified LeNet-5: Conv1 3×3 (spike encoder) with 14
  channels, Conv2/Conv3 3×3×14 (fan-in 126 ≤ 128) and two FC layers
  mapped on IMPULSE; 10 output neurons integrate class evidence.

The float models use a triangular surrogate gradient (Diet-SNN) with
trainable per-layer thresholds; RMP (soft-reset) neurons throughout.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.snn_step import encoder_step, snn_step

# ---------------------------------------------------------------------------
# Surrogate-gradient spike
# ---------------------------------------------------------------------------

SURROGATE_SCALE = 0.3  # Diet-SNN's linear surrogate scale


@jax.custom_vjp
def spike_fn(v, thr):
    """Heaviside spike with triangular surrogate derivative."""
    return (v >= thr).astype(jnp.float32)


def _spike_fwd(v, thr):
    return spike_fn(v, thr), (v, thr)


def _spike_bwd(resid, g):
    v, thr = resid
    x = (v - thr) / jnp.maximum(thr, 1e-3)
    grad = SURROGATE_SCALE * jnp.maximum(0.0, 1.0 - jnp.abs(x)) / jnp.maximum(thr, 1e-3)
    gv = g * grad
    # thr is a scalar per layer: reduce fully.
    gthr = jnp.reshape(-jnp.sum(gv), jnp.shape(thr))
    return gv, gthr


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def rmp_update(v, x, thr):
    """Float RMP neuron: integrate, fire, soft-reset. Returns (v', s)."""
    v1 = v + x
    s = spike_fn(v1, thr)
    return v1 - s * thr, s


# ---------------------------------------------------------------------------
# Sentiment network — float training model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SentimentDims:
    emb: int = 100
    h1: int = 128
    h2: int = 128
    t_word: int = 10  # timesteps per word


def init_sentiment_params(key, dims: SentimentDims = SentimentDims()):
    k1, k2, k3 = jax.random.split(key, 3)
    glorot = jax.nn.initializers.glorot_uniform()
    return {
        "w1": glorot(k1, (dims.emb, dims.h1), jnp.float32),
        "w2": glorot(k2, (dims.h1, dims.h2), jnp.float32),
        "w_out": glorot(k3, (dims.h2, 1), jnp.float32) * 0.5,
        "log_thr_enc": jnp.log(jnp.asarray(1.0)),
        "log_thr1": jnp.log(jnp.asarray(1.0)),
        "log_thr2": jnp.log(jnp.asarray(1.0)),
    }


def count_sentiment_params(params) -> int:
    """Trainable parameter count (the paper reports 29.3K)."""
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def sentiment_forward_float(params, emb_seq, word_mask, dims: SentimentDims = SentimentDims()):
    """Run the float SNN over a padded batch of embedded word sequences.

    emb_seq:   [B, L, 100] embeddings (already gathered),
    word_mask: [B, L] 1.0 for real words, 0.0 for padding.

    Returns (v_out_final [B], aux dict with spike-rate stats and the
    per-word output-potential trace [B, L]).
    """
    b, l, _ = emb_seq.shape
    thr_e = jnp.exp(params["log_thr_enc"])
    thr1 = jnp.exp(params["log_thr1"])
    thr2 = jnp.exp(params["log_thr2"])

    def word_step(carry, inputs):
        v_e, v1, v2, v_o, ext = carry
        x, m = inputs  # x: [B, 100], m: [B]

        def tstep(c, _):
            v_e, v1, v2, v_o, acc, ext = c
            v_e, s0 = rmp_update(v_e, x * m[:, None], thr_e)
            v1, s1 = rmp_update(v1, s0 @ params["w1"], thr1)
            v2, s2 = rmp_update(v2, s1 @ params["w2"], thr2)
            v_o = v_o + (s2 @ params["w_out"])[:, 0] * m
            acc = acc + jnp.stack([s0.mean(), s1.mean(), s2.mean()])
            # track per-layer |V| extremes (drives quantization scales
            # and the negative-drift penalty)
            ext = jnp.maximum(
                ext,
                jnp.stack(
                    [jnp.abs(v1).max(), jnp.abs(v2).max(), jnp.abs(v_o).max()]
                ),
            )
            return (v_e, v1, v2, v_o, acc, ext), None

        (v_e, v1, v2, v_o, acc, ext), _ = jax.lax.scan(
            tstep, (v_e, v1, v2, v_o, jnp.zeros(3), ext), None, length=dims.t_word
        )
        return (v_e, v1, v2, v_o, ext), (v_o, acc / dims.t_word)

    init = (
        jnp.zeros((b, dims.emb)),
        jnp.zeros((b, dims.h1)),
        jnp.zeros((b, dims.h2)),
        jnp.zeros((b,)),
        jnp.zeros(3),
    )
    (v_e, v1, v2, v_o, ext), (v_o_trace, rates) = jax.lax.scan(
        word_step, init, (jnp.swapaxes(emb_seq, 0, 1), jnp.swapaxes(word_mask, 0, 1))
    )
    aux = {
        "v_out_trace": jnp.swapaxes(v_o_trace, 0, 1),  # [B, L]
        "spike_rates": rates.mean(axis=0),  # [3]
        "v_extremes": ext,  # [3] max |V| of v1, v2, v_out
        "final_v": (v1, v2),
    }
    return v_o, aux


def sentiment_loss(params, emb_seq, word_mask, labels, rate_penalty=0.02,
                   drift_penalty=0.01):
    v_out, aux = sentiment_forward_float(params, emb_seq, word_mask)
    logits = v_out * 0.5
    labels_f = labels.astype(jnp.float32)
    bce = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels_f + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    # mild spike-rate penalty: pushes toward the paper's ~85% sparsity
    rate = aux["spike_rates"].mean()
    # negative-drift penalty: RMP neurons with persistent inhibitory
    # drive sink without bound in float, but on the macro V wraps at
    # −1024 and spuriously spikes. Penalize V sinking below −4·θ so the
    # trained net fits the 11-bit rails after quantization.
    thr1 = jnp.exp(params["log_thr1"])
    thr2 = jnp.exp(params["log_thr2"])
    v1, v2 = aux["final_v"]
    drift = (
        jnp.mean(jax.nn.relu(-v1 - 4.0 * thr1))
        + jnp.mean(jax.nn.relu(-v2 - 4.0 * thr2))
    )
    return bce + rate_penalty * rate + drift_penalty * drift, (v_out, aux)


# ---------------------------------------------------------------------------
# Sentiment network — hardware-exact integer inference
# ---------------------------------------------------------------------------


@dataclass
class QuantSentiment:
    """Quantized model artifact (ints only — what the macro executes)."""

    emb_q: np.ndarray  # [vocab, 100] i32 quantized embeddings
    w1: np.ndarray  # [100, 128] i32 in [-32, 31]
    w2: np.ndarray  # [128, 128] i32
    w_out: np.ndarray  # [128, 1] i32
    thr_enc: int
    thr1: int
    thr2: int

    def params_i8(self):
        return {
            "w1": self.w1.astype(np.int8),
            "w2": self.w2.astype(np.int8),
            "w_out": self.w_out.astype(np.int8),
        }


def sentiment_step_int(w1, w2, w_out, thr_enc, thr1, thr2, x_q, v_e, v1, v2, v_o):
    """One hardware-exact timestep of the quantized sentiment SNN.

    All ints. The two FC layers and the output accumulation follow
    IMPULSE semantics (11-bit wrap, RMP); the encoder is off-macro
    (plain i32). This is the function AOT-exported to HLO for the Rust
    runtime, built on the Pallas kernels.
    """
    v_e, s0 = encoder_step(x_q, v_e, thr_enc)
    v1, s1 = snn_step(s0, w1, v1, thr1, mode=ref.RMP)
    v2, s2 = snn_step(s1, w2, v2, thr2, mode=ref.RMP)
    # Output neuron: mapped on the macro ⇒ 11-bit wrapped accumulate.
    acc = jnp.matmul(s2, w_out, preferred_element_type=jnp.int32)
    v_o = ref.wrap11(v_o + acc)
    return v_e, v1, v2, v_o, (s0, s1, s2)


def sentiment_infer_int(q: QuantSentiment, seqs_padded, lens, t_word=10):
    """Full integer inference over a padded batch. Returns predictions,
    the per-word V_out trace, and per-layer spike counts (for Fig 11a).
    """
    b, l = seqs_padded.shape
    w1 = jnp.asarray(q.w1, jnp.int32)
    w2 = jnp.asarray(q.w2, jnp.int32)
    w_out = jnp.asarray(q.w_out, jnp.int32)
    emb = jnp.asarray(q.emb_q, jnp.int32)

    v_e = jnp.zeros((b, emb.shape[1]), jnp.int32)
    v1 = jnp.zeros((b, w1.shape[1]), jnp.int32)
    v2 = jnp.zeros((b, w2.shape[1]), jnp.int32)
    v_o = jnp.zeros((b, 1), jnp.int32)

    ids = jnp.clip(jnp.asarray(seqs_padded, jnp.int32), 0, emb.shape[0] - 1)
    mask = (jnp.arange(l)[None, :] < jnp.asarray(lens)[:, None]).astype(jnp.int32)

    traces = []
    spike_counts = np.zeros(3, dtype=np.int64)
    spike_total = np.zeros(3, dtype=np.int64)
    for w in range(l):
        x_q = emb[ids[:, w]] * mask[:, w : w + 1]
        for _ in range(t_word):
            v_e, v1, v2, v_o_new, (s0, s1, s2) = sentiment_step_int(
                w1, w2, w_out, q.thr_enc, q.thr1, q.thr2, x_q, v_e, v1, v2, v_o
            )
            # freeze output accumulation on padded words
            v_o = jnp.where(mask[:, w : w + 1] == 1, v_o_new, v_o)
            for i, s in enumerate((s0, s1, s2)):
                sm = np.asarray(s) * np.asarray(mask[:, w : w + 1])
                spike_counts[i] += sm.sum()
                spike_total[i] += int(mask[:, w].sum()) * s.shape[1]
        traces.append(np.asarray(v_o[:, 0]))
    preds = (np.asarray(v_o[:, 0]) >= 0).astype(np.uint8)
    sparsity = 1.0 - spike_counts / np.maximum(spike_total, 1)
    return preds, np.stack(traces, axis=1), sparsity


# ---------------------------------------------------------------------------
# Digits network — float training model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DigitsDims:
    channels: int = 14
    fc1: int = 100
    classes: int = 10
    t: int = 10


def init_digits_params(key, dims: DigitsDims = DigitsDims()):
    ks = jax.random.split(key, 5)
    glorot = jax.nn.initializers.glorot_uniform()
    c = dims.channels
    return {
        "k1": glorot(ks[0], (3, 3, 1, c), jnp.float32),
        "k2": glorot(ks[1], (3, 3, c, c), jnp.float32),
        "k3": glorot(ks[2], (3, 3, c, c), jnp.float32),
        "w_fc1": glorot(ks[3], (3 * 3 * c, dims.fc1), jnp.float32),
        "w_fc2": glorot(ks[4], (dims.fc1, dims.classes), jnp.float32) * 0.5,
        "log_thr_c1": jnp.log(jnp.asarray(0.5)),
        "log_thr_c2": jnp.log(jnp.asarray(1.0)),
        "log_thr_c3": jnp.log(jnp.asarray(1.0)),
        "log_thr_f1": jnp.log(jnp.asarray(1.0)),
    }


def count_digits_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def _conv(x, k):
    return jax.lax.conv_general_dilated(
        x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def digits_forward_float(params, images, dims: DigitsDims = DigitsDims()):
    """Float digits SNN over T timesteps. images: [B, 28, 28, 1]."""
    b = images.shape[0]
    c = dims.channels
    thr = {k: jnp.exp(params[f"log_thr_{k}"]) for k in ("c1", "c2", "c3", "f1")}

    def tstep(carry, _):
        v1, v2, v3, vf, vo, acc, ext = carry
        v1, s1 = rmp_update(v1, _conv(images, params["k1"]), thr["c1"])
        p1 = _maxpool2(s1)  # [B,14,14,C] binary
        v2, s2 = rmp_update(v2, _conv(p1, params["k2"]), thr["c2"])
        p2 = _maxpool2(s2)  # [B,7,7,C]
        v3, s3 = rmp_update(v3, _conv(p2, params["k3"]), thr["c3"])
        p3 = _maxpool2(s3)  # [B,3,3,C]
        flat = p3.reshape(b, -1)
        vf, sf = rmp_update(vf, flat @ params["w_fc1"], thr["f1"])
        vo = vo + sf @ params["w_fc2"]
        acc = acc + jnp.stack([s1.mean(), s2.mean(), s3.mean(), sf.mean()])
        ext = jnp.maximum(
            ext,
            jnp.stack(
                [
                    jnp.abs(v2).max(),
                    jnp.abs(v3).max(),
                    jnp.abs(vf).max(),
                    jnp.abs(vo).max(),
                ]
            ),
        )
        return (v1, v2, v3, vf, vo, acc, ext), None

    init = (
        jnp.zeros((b, 28, 28, c)),
        jnp.zeros((b, 14, 14, c)),
        jnp.zeros((b, 7, 7, c)),
        jnp.zeros((b, dims.fc1)),
        jnp.zeros((b, dims.classes)),
        jnp.zeros(4),
        jnp.zeros(4),
    )
    (v1, v2, v3, vf, vo, acc, ext), _ = jax.lax.scan(tstep, init, None, length=dims.t)
    return vo, (acc / dims.t, (v2, v3, vf), ext)


def digits_loss(params, images, labels, rate_penalty=0.02, drift_penalty=0.01):
    logits, (rates, finals, ext) = digits_forward_float(params, images)
    onehot = jax.nn.one_hot(labels, logits.shape[-1])
    ce = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    thr = [jnp.exp(params[f"log_thr_{k}"]) for k in ("c2", "c3", "f1")]
    drift = sum(
        jnp.mean(jax.nn.relu(-v - 4.0 * t)) for v, t in zip(finals, thr)
    )
    return ce + rate_penalty * rates.mean() + drift_penalty * drift, (logits, rates, ext)


# ---------------------------------------------------------------------------
# Digits network — hardware-exact integer inference
# ---------------------------------------------------------------------------


@dataclass
class QuantDigits:
    k1: np.ndarray  # [3,3,1,C] f32 — encoder conv stays off-macro/float
    thr_c1_f: float
    k2: np.ndarray  # [3,3,C,C] i32
    k3: np.ndarray  # [3,3,C,C] i32
    w_fc1: np.ndarray  # [126, FC1] i32
    w_fc2: np.ndarray  # [FC1, 10] i32
    thr_c2: int
    thr_c3: int
    thr_f1: int


def _conv_int(x, k):
    return jax.lax.conv_general_dilated(
        x,
        k,
        (1, 1),
        "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )


def _maxpool2_int(x):
    return jax.lax.reduce_window(
        x, jnp.iinfo(jnp.int32).min, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def digits_infer_int(q: QuantDigits, images, t=10):
    """Hardware-exact integer inference for the digits SNN.

    Conv1 (the spike encoder) runs in float off-macro, as in the paper;
    Conv2/Conv3/FC1/FC2 use IMPULSE semantics (11-bit wrap + RMP spike,
    int weights). Max-pool on binary spikes is a logical OR.
    Returns (predictions, per-layer sparsity [4]).
    """
    b = images.shape[0]
    c = q.k2.shape[2]
    x1 = _conv(images, jnp.asarray(q.k1))  # constant input current
    v1 = jnp.zeros((b, 28, 28, c), jnp.float32)
    v2 = jnp.zeros((b, 14, 14, c), jnp.int32)
    v3 = jnp.zeros((b, 7, 7, c), jnp.int32)
    vf = jnp.zeros((b, q.w_fc1.shape[1]), jnp.int32)
    vo = jnp.zeros((b, q.w_fc2.shape[1]), jnp.int32)

    k2 = jnp.asarray(q.k2, jnp.int32)
    k3 = jnp.asarray(q.k3, jnp.int32)
    wf1 = jnp.asarray(q.w_fc1, jnp.int32)
    wf2 = jnp.asarray(q.w_fc2, jnp.int32)

    spike_counts = np.zeros(4, dtype=np.int64)
    spike_total = np.zeros(4, dtype=np.int64)
    for _ in range(t):
        # encoder (float, off-macro)
        v1 = v1 + x1
        s1 = (v1 >= q.thr_c1_f).astype(jnp.int32)
        v1 = jnp.where(s1 == 1, v1 - q.thr_c1_f, v1)
        p1 = _maxpool2_int(s1)
        # conv2 (on-macro)
        v2 = ref.wrap11(v2 + _conv_int(p1, k2))
        s2 = ref.spike_of(v2, q.thr_c2)
        v2 = jnp.where(s2 == 1, ref.wrap11(v2 - q.thr_c2), v2)
        p2 = _maxpool2_int(s2)
        # conv3 (on-macro)
        v3 = ref.wrap11(v3 + _conv_int(p2, k3))
        s3 = ref.spike_of(v3, q.thr_c3)
        v3 = jnp.where(s3 == 1, ref.wrap11(v3 - q.thr_c3), v3)
        p3 = _maxpool2_int(s3)
        # fc1 (on-macro)
        flat = p3.reshape(b, -1)
        vf = ref.wrap11(vf + jnp.matmul(flat, wf1, preferred_element_type=jnp.int32))
        sf = ref.spike_of(vf, q.thr_f1)
        vf = jnp.where(sf == 1, ref.wrap11(vf - q.thr_f1), vf)
        # output accumulate (on-macro)
        vo = ref.wrap11(vo + jnp.matmul(sf, wf2, preferred_element_type=jnp.int32))
        for i, s in enumerate((s1, s2, s3, sf)):
            spike_counts[i] += int(np.asarray(s).sum())
            spike_total[i] += int(np.prod(s.shape))
    preds = np.asarray(jnp.argmax(vo, axis=-1)).astype(np.uint8)
    sparsity = 1.0 - spike_counts / np.maximum(spike_total, 1)
    return preds, sparsity
