"""Artifact binary tensor format shared with the Rust loaders.

Layout (little-endian):

    magic   4 bytes  b"IMPT"
    dtype   u8       0=i8 1=i16 2=i32 3=f32 4=i64 5=f64 6=u8
    rank    u8
    dims    rank * u32
    data    prod(dims) * sizeof(dtype), row-major

A companion ``manifest.txt`` carries ``key=value`` metadata lines.
The format is deliberately dependency-free so the offline Rust side can
read it with std only (see ``rust/src/data/binfmt.rs``).
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"IMPT"

_DTYPES = {
    np.dtype(np.int8): 0,
    np.dtype(np.int16): 1,
    np.dtype(np.int32): 2,
    np.dtype(np.float32): 3,
    np.dtype(np.int64): 4,
    np.dtype(np.float64): 5,
    np.dtype(np.uint8): 6,
}
_CODES = {v: k for k, v in _DTYPES.items()}


def write_tensor(path: str | Path, arr: np.ndarray) -> None:
    """Serialize a numpy array to the IMPT format."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DTYPES:
        raise TypeError(f"unsupported dtype {arr.dtype}")
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_tensor(path: str | Path) -> np.ndarray:
    """Deserialize an IMPT tensor."""
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        dtype_code, rank = struct.unpack("<BB", f.read(2))
        dims = struct.unpack(f"<{rank}I", f.read(4 * rank))
        dt = _CODES[dtype_code].newbyteorder("<")
        n = int(np.prod(dims)) if rank else 1
        data = np.frombuffer(f.read(n * dt.itemsize), dtype=dt)
        return data.reshape(dims).astype(_CODES[dtype_code])


def write_manifest(path: str | Path, entries: dict) -> None:
    """Write key=value metadata lines (stable order)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        for k in sorted(entries):
            v = entries[k]
            f.write(f"{k}={v}\n")


def read_manifest(path: str | Path) -> dict:
    out = {}
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        k, _, v = line.partition("=")
        out[k] = v
    return out
